//! The measured-wire cluster engine: leader + K workers as real OS threads
//! shipping entropy-coded [`WirePacket`] bytes over localhost TCP.
//!
//! This is the repo's third coordinator engine. The other two charge an
//! analytic clock; here `comm_s` is **measured** — a monotonic
//! [`Instant`] wraps every socket send/recv phase, and nothing in this
//! module (or anywhere under `wire/`) calls the analytic charge model.
//! The split into exposed vs hidden seconds reuses
//! [`ExchangePlan::split`], exactly the accounting `PhaseTimeline` applies
//! to modeled charges — same semantics, measured input.
//!
//! Aggregates stay bit-identical to `ClusterSim` and the threaded engine
//! *by construction*: every node decodes the full packet set through
//! [`decode_aggregate_into`] (node order, `v/k` folds) with codecs seeded
//! by the shared [`worker_codec_seed`] / [`worker_oracle_seed`] formulas —
//! there is no wire-local copy of the aggregation arithmetic.
//!
//! Round flow (flat star): every worker encodes its dual and sends a
//! round-tagged `Packet` to the leader; the leader gathers all K, then
//! multicasts the full set back down as one `Bundle`; every node decodes
//! all K packets locally and applies the same deterministic update — an
//! allgather, so the downlink carries coded bytes, not f64 iterates.
//! Hierarchical: members send to their rack leader, rack leaders forward
//! gathered bundles up, the leader multicasts the full set to rack leaders
//! only, and rack leaders fan it down — the leader's serialized egress
//! shrinks from K to R copies with the fan-out parallelized across racks,
//! which is where the measured hierarchical win at K = 12 comes from.
//!
//! Overlapped exchanges follow the threaded engine's depth-stale schedule
//! verbatim (send round t+1 before consuming round t, stage aggregates,
//! drain at the end). To keep the pipeline deadlock-free against finite
//! kernel socket buffers, the leader reads round t+1's uplink *before*
//! writing round t's downlink — every peer that could be mid-write is
//! drained before a large write heads their way.

use super::frame::{
    bundle_frame_bytes, packet_frame_bytes, read_frame, read_frame_bytes,
    write_all_bytes, write_frame, Frame,
};
use super::socket::{accept_configured, bind_ephemeral, connect_with_backoff, SocketConfig};
use crate::comm::{CommError, Compressor, IdentityCompressor, WirePacket};
use crate::coordinator::core::decode_aggregate_into;
use crate::coordinator::parallel::{worker_codec_seed, worker_oracle_seed, SharedQuantState};
use crate::coordinator::topology::{rack_spans, ExchangeMode, ExchangePlan, TopologySpec};
use crate::oda::driver::{MetricsSink, StepRecord, StepStats};
use crate::stats::rng::Rng;
use crate::vi::noise::{NoiseModel, Oracle};
use crate::vi::operator::Operator;
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

/// What each worker feeds the codec every round.
#[derive(Clone, Copy)]
pub enum Workload<'a> {
    /// A VI oracle: `g = A(x) + noise`, seeded with the engines' shared
    /// per-node formula — the parity-pinned mode.
    Oracle { op: &'a dyn Operator, noise: NoiseModel },
    /// Seeded Gaussian duals of dimension `dim`, independent of `x` — the
    /// timing-bench mode, where `dim` can be paper-sized without paying a
    /// dense operator apply.
    Synthetic { dim: usize, scale: f64 },
}

impl Workload<'_> {
    pub fn dim(&self) -> usize {
        match self {
            Workload::Oracle { op, .. } => op.dim(),
            Workload::Synthetic { dim, .. } => *dim,
        }
    }
}

/// The synchronized codec every node builds locally (codebooks never travel
/// on the wire — same contract as the in-process engines).
#[derive(Clone)]
pub enum WireCodecSpec {
    /// fp32 on the wire: the uncompressed collective baseline.
    Identity,
    /// The paper's quantize + entropy-code scheme under synchronized fixed
    /// state; per-node encoder RNGs use [`worker_codec_seed`].
    Quant(SharedQuantState),
}

impl WireCodecSpec {
    fn encoder(&self, seed: u64, node: usize) -> Box<dyn Compressor> {
        match self {
            WireCodecSpec::Identity => Box::new(IdentityCompressor::new()),
            WireCodecSpec::Quant(st) => Box::new(st.codec(worker_codec_seed(seed, node))),
        }
    }

    fn decoder(&self) -> Box<dyn Compressor> {
        match self {
            WireCodecSpec::Identity => Box::new(IdentityCompressor::new()),
            // decode draws no randomness; seed 0 mirrors the threaded
            // engine's leader decoder
            WireCodecSpec::Quant(st) => Box::new(st.codec(0)),
        }
    }
}

/// Engine knobs beyond the socket layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireOptions {
    pub socket: SocketConfig,
    /// Test hook: `(node, round)` — that worker drops its connections
    /// instead of producing that round's packet, so the suite can prove a
    /// mid-round death surfaces as [`CommError::WorkerLost`] within the
    /// read timeout instead of deadlocking.
    pub kill: Option<(usize, usize)>,
}

impl WireOptions {
    pub fn with_kill(mut self, node: usize, round: usize) -> Self {
        self.kill = Some((node, round));
        self
    }
}

/// Per-round measured timing, all from the leader's monotonic clock.
#[derive(Clone, Copy, Debug)]
pub struct WireRoundRecord {
    pub round: usize,
    /// seconds the leader spent blocked in socket reads this round
    /// (under an overlapped exchange this includes the next round's
    /// drained uplink — total comm is exact, per-round attribution is the
    /// pipeline's)
    pub gather_s: f64,
    /// seconds the leader spent writing the full-set downlink
    pub broadcast_s: f64,
    /// `gather_s + broadcast_s`
    pub comm_s: f64,
    /// exposed share under the run's [`ExchangePlan`]
    pub comm_exposed_s: f64,
    /// hidden share (`comm_exposed_s + comm_hidden_s == comm_s`)
    pub comm_hidden_s: f64,
    /// sum of the K packets' exact payload bits — the same number the
    /// analytic engines charge for a flat exchange
    pub payload_bits: u64,
    /// framed bytes the leader itself moved (sent + received) this round
    pub frame_bytes: u64,
}

/// What a measured wire run produced.
#[derive(Clone, Debug)]
pub struct WireReport {
    /// final iterate (the leader's replica; every worker's copy is
    /// debug-asserted identical)
    pub x: Vec<f64>,
    /// mean decoded vector of the last round
    pub last_mean: Vec<f64>,
    /// each node's decoded dual of the last round (parity pinning)
    pub last_decoded: Vec<Vec<f64>>,
    /// total payload bits across rounds (flat accounting: each packet
    /// counted once — comparable to `ClusterSim`'s flat `wire_bits`)
    pub payload_bits: u64,
    /// total framed bytes sent across every socket by every thread
    pub frame_bytes: u64,
    /// total measured comm seconds (leader clock)
    pub comm_s: f64,
    pub comm_exposed_s: f64,
    pub comm_hidden_s: f64,
    /// per-round measured records
    pub rounds: Vec<WireRoundRecord>,
    /// each node's OS-assigned ephemeral source port, collected during the
    /// handshake (no fixed ports anywhere)
    pub node_ports: Vec<u16>,
}

/// A node's role in the physical star, derived from the run's topology.
#[derive(Clone, Debug)]
enum Role {
    /// talks straight to the leader (flat and parameter-server plans)
    Flat,
    /// talks to the leader and relays for `members`
    RackLeader { members: Vec<usize> },
    /// talks to its rack leader (port learned via the handshake)
    Member,
}

struct WorkerExit {
    x: Vec<f64>,
    sent: u64,
}

enum WorkerSource<'a> {
    Oracle(Oracle<'a>),
    Synthetic { rng: Rng, dim: usize, scale: f64 },
}

impl<'a> WorkerSource<'a> {
    fn new(w: &Workload<'a>, seed: u64, node: usize) -> WorkerSource<'a> {
        match *w {
            Workload::Oracle { op, noise } => {
                WorkerSource::Oracle(Oracle::new(op, noise, worker_oracle_seed(seed, node)))
            }
            Workload::Synthetic { dim, scale } => WorkerSource::Synthetic {
                rng: Rng::new(worker_oracle_seed(seed, node)),
                dim,
                scale,
            },
        }
    }

    fn sample(&mut self, x: &[f64]) -> Vec<f64> {
        match self {
            WorkerSource::Oracle(o) => o.sample(x),
            WorkerSource::Synthetic { rng, dim, scale } => {
                (0..*dim).map(|_| *scale * rng.gaussian()).collect()
            }
        }
    }
}

/// Receive one round-tagged packet from every member, in member order
/// (per-socket FIFO means the first unread frame is always the expected
/// round; a mismatch is a protocol break — the peer counts as lost).
fn recv_member_packets(
    members: &mut [(usize, TcpStream)],
    round: usize,
) -> Result<Vec<(u32, WirePacket)>, CommError> {
    let mut out = Vec::with_capacity(members.len());
    for (node, s) in members.iter_mut() {
        match read_frame(s)? {
            (Frame::Packet { node: n, round: r, packet }, _)
                if n as usize == *node && r == round as u64 =>
            {
                out.push((n, packet))
            }
            _ => return Err(CommError::WorkerLost),
        }
    }
    Ok(out)
}

/// Receive the full-set bundle for `round` from the parent, returning the
/// node-indexed set plus the raw frame bytes (for verbatim fan-down).
fn recv_full_set(
    parent: &mut TcpStream,
    round: usize,
    k: usize,
) -> Result<(Vec<Option<WirePacket>>, Vec<u8>), CommError> {
    let (frame, raw) = read_frame_bytes(parent)?;
    match frame {
        Frame::Bundle { round: r, packets } if r == round as u64 => {
            let mut set: Vec<Option<WirePacket>> = (0..k).map(|_| None).collect();
            for (n, p) in packets {
                let idx = n as usize;
                if idx >= k || set[idx].is_some() {
                    return Err(CommError::WorkerLost);
                }
                set[idx] = Some(p);
            }
            if set.iter().any(|s| s.is_none()) {
                return Err(CommError::WorkerLost);
            }
            Ok((set, raw))
        }
        _ => Err(CommError::WorkerLost),
    }
}

/// Decode all K packets in node order through the shared aggregate core.
fn aggregate_set(
    set: &[Option<WirePacket>],
    dec: &mut dyn Compressor,
    k: usize,
    d: usize,
    mean: &mut Vec<f64>,
    scratch: &mut Vec<f64>,
) -> Result<(), CommError> {
    decode_aggregate_into(k, d, mean, scratch, |node, out| match set[node].as_ref() {
        Some(p) => dec.decode_into(p, out),
        None => Err(CommError::WorkerLost),
    })
}

/// Encode this node's round-`t` dual at `x` and ship it up: a `Packet`
/// frame for plain workers, a gathered `Bundle` (own + members, node
/// order) for rack leaders. Returns `Ok(false)` when the kill hook fired.
#[allow(clippy::too_many_arguments)]
fn send_up(
    t: usize,
    node: usize,
    is_rack_leader: bool,
    members: &mut [(usize, TcpStream)],
    parent: &mut TcpStream,
    source: &mut WorkerSource<'_>,
    enc: &mut dyn Compressor,
    own: &mut WirePacket,
    x: &[f64],
    kill: Option<(usize, usize)>,
    sent: &mut u64,
) -> Result<bool, CommError> {
    if kill == Some((node, t)) {
        return Ok(false);
    }
    let dual = source.sample(x);
    enc.encode_into(&dual, own)?;
    if is_rack_leader {
        let kids = recv_member_packets(members, t)?;
        let mut refs: Vec<(u32, &WirePacket)> = Vec::with_capacity(1 + kids.len());
        refs.push((node as u32, own));
        for (n, p) in &kids {
            refs.push((*n, p));
        }
        refs.sort_by_key(|(n, _)| *n);
        let bytes = bundle_frame_bytes(t as u64, &refs)?;
        *sent += write_all_bytes(parent, &bytes)?;
    } else {
        let bytes = packet_frame_bytes(node as u32, t as u64, own)?;
        *sent += write_all_bytes(parent, &bytes)?;
    }
    Ok(true)
}

struct WorkerCfg<'a> {
    node: usize,
    k: usize,
    leader_addr: SocketAddr,
    role: Role,
    workload: Workload<'a>,
    codec: &'a WireCodecSpec,
    x0: &'a [f64],
    steps: usize,
    seed: u64,
    plan: ExchangePlan,
    opts: WireOptions,
    update: &'a (dyn Fn(&mut Vec<f64>, &[f64], usize) + Sync),
}

fn worker_main(cfg: WorkerCfg<'_>) -> Result<WorkerExit, CommError> {
    let d = cfg.workload.dim();
    let sock = cfg.opts.socket;
    let is_rack_leader = matches!(cfg.role, Role::RackLeader { .. });

    // rack leaders bind their member-facing listener *before* dialing in
    // so the OS-assigned port rides in the Hello
    let listener = match &cfg.role {
        Role::RackLeader { members } if !members.is_empty() => Some(bind_ephemeral()?),
        _ => None,
    };
    let listen_port = listener.as_ref().map_or(0, |(_, p)| *p);

    let mut leader = connect_with_backoff(cfg.leader_addr, &sock)?;
    let mut sent = 0u64;
    sent += write_frame(&mut leader, &Frame::Hello { node: cfg.node as u32, listen_port })?;
    let parent_port = match read_frame(&mut leader)? {
        (Frame::Welcome { node, parent_port }, _) if node as usize == cfg.node => parent_port,
        _ => return Err(CommError::WorkerLost),
    };

    // establish the data plane
    let mut parent: TcpStream;
    let mut members: Vec<(usize, TcpStream)> = Vec::new();
    match &cfg.role {
        Role::Member => {
            // the leader stream was handshake-only; rounds go via the rack
            // leader's collected port
            let addr: SocketAddr = ([127, 0, 0, 1], parent_port).into();
            parent = connect_with_backoff(addr, &sock)?;
            sent += write_frame(
                &mut parent,
                &Frame::Hello { node: cfg.node as u32, listen_port: 0 },
            )?;
            drop(leader);
        }
        Role::Flat => parent = leader,
        Role::RackLeader { members: want } => {
            parent = leader;
            if let Some((l, _)) = &listener {
                for _ in 0..want.len() {
                    let mut s = accept_configured(l, &sock)?;
                    let who = match read_frame(&mut s)? {
                        (Frame::Hello { node, .. }, _) => node as usize,
                        _ => return Err(CommError::WorkerLost),
                    };
                    if !want.contains(&who) {
                        return Err(CommError::WorkerLost);
                    }
                    members.push((who, s));
                }
                members.sort_by_key(|(n, _)| *n);
            }
        }
    }
    drop(listener);

    let mut enc = cfg.codec.encoder(cfg.seed, cfg.node);
    let mut dec = cfg.codec.decoder();
    let mut source = WorkerSource::new(&cfg.workload, cfg.seed, cfg.node);
    let mut x = cfg.x0.to_vec();
    let mut own = WirePacket::new();
    let mut mean: Vec<f64> = Vec::new();
    let mut scratch: Vec<f64> = Vec::new();
    let kill = cfg.opts.kill;
    let update = cfg.update;

    match cfg.plan.mode {
        ExchangeMode::Synchronous => {
            for t in 1..=cfg.steps {
                if !send_up(
                    t,
                    cfg.node,
                    is_rack_leader,
                    &mut members,
                    &mut parent,
                    &mut source,
                    enc.as_mut(),
                    &mut own,
                    &x,
                    kill,
                    &mut sent,
                )? {
                    return Ok(WorkerExit { x, sent });
                }
                let (set, raw) = recv_full_set(&mut parent, t, cfg.k)?;
                for (_, s) in members.iter_mut() {
                    sent += write_all_bytes(s, &raw)?;
                }
                aggregate_set(&set, dec.as_mut(), cfg.k, d, &mut mean, &mut scratch)?;
                update(&mut x, &mean, t);
            }
        }
        ExchangeMode::Overlapped { depth } => {
            let depth = depth.max(1);
            // aggregates decoded but not yet applied: the node-side double
            // buffer, identical to the threaded engine's schedule
            let mut staged: VecDeque<(usize, Vec<f64>)> = VecDeque::new();
            if cfg.steps > 0
                && !send_up(
                    1,
                    cfg.node,
                    is_rack_leader,
                    &mut members,
                    &mut parent,
                    &mut source,
                    enc.as_mut(),
                    &mut own,
                    &x,
                    kill,
                    &mut sent,
                )?
            {
                return Ok(WorkerExit { x, sent });
            }
            for t in 1..=cfg.steps {
                if t < cfg.steps {
                    if staged.front().map_or(false, |&(r, _)| r + depth <= t) {
                        if let Some((r, m)) = staged.pop_front() {
                            update(&mut x, &m, r);
                        }
                    }
                    // round t+1 goes up *before* round t's downlink is
                    // consumed — this is the genuine overlap, and the
                    // leader drains it before writing the big bundle
                    if !send_up(
                        t + 1,
                        cfg.node,
                        is_rack_leader,
                        &mut members,
                        &mut parent,
                        &mut source,
                        enc.as_mut(),
                        &mut own,
                        &x,
                        kill,
                        &mut sent,
                    )? {
                        return Ok(WorkerExit { x, sent });
                    }
                }
                let (set, raw) = recv_full_set(&mut parent, t, cfg.k)?;
                for (_, s) in members.iter_mut() {
                    sent += write_all_bytes(s, &raw)?;
                }
                aggregate_set(&set, dec.as_mut(), cfg.k, d, &mut mean, &mut scratch)?;
                staged.push_back((t, mean.clone()));
            }
            while let Some((r, m)) = staged.pop_front() {
                update(&mut x, &m, r);
            }
        }
    }
    Ok(WorkerExit { x, sent })
}

/// One round's gathered uplink at the leader.
struct RoundIn {
    set: Vec<Option<WirePacket>>,
    payload_bits: u64,
    recv_bytes: u64,
}

/// Run a measured wire exchange: `steps` rounds over real localhost TCP
/// with `k` worker threads, each node holding an identical iterate replica
/// advanced by `update`. See the module docs for the round flow; see
/// [`run_wire_observed`] for streaming per-round records to sinks.
#[allow(clippy::too_many_arguments)]
pub fn run_wire(
    workload: Workload<'_>,
    k: usize,
    codec: &WireCodecSpec,
    x0: &[f64],
    steps: usize,
    seed: u64,
    topology: &TopologySpec,
    plan: ExchangePlan,
    opts: &WireOptions,
    update: &(dyn Fn(&mut Vec<f64>, &[f64], usize) + Sync),
) -> Result<WireReport, CommError> {
    run_wire_observed(
        workload, k, codec, x0, steps, seed, topology, plan, opts, update, &mut [],
    )
}

/// [`run_wire`] with live [`MetricsSink`] streaming: every round emits a
/// [`StepRecord`] whose `comm_s` / exposed / hidden fields are *measured*
/// seconds from the leader's monotonic clock.
#[allow(clippy::too_many_arguments)]
pub fn run_wire_observed(
    workload: Workload<'_>,
    k: usize,
    codec: &WireCodecSpec,
    x0: &[f64],
    steps: usize,
    seed: u64,
    topology: &TopologySpec,
    plan: ExchangePlan,
    opts: &WireOptions,
    update: &(dyn Fn(&mut Vec<f64>, &[f64], usize) + Sync),
    sinks: &mut [&mut dyn MetricsSink],
) -> Result<WireReport, CommError> {
    let d = workload.dim();
    assert!(k >= 1, "a wire run needs at least one worker");
    assert_eq!(x0.len(), d, "x0 dimension must match the workload");

    // the physical plan: contiguous rack spans for hierarchical runs, the
    // plain star otherwise (parameter-server already *is* a star)
    let spans: Option<Vec<(usize, usize)>> = match topology {
        TopologySpec::Hierarchical { racks } => Some(rack_spans(k, *racks)),
        _ => None,
    };
    let role_of = |node: usize| -> Role {
        match &spans {
            None => Role::Flat,
            Some(spans) => {
                for &(start, end) in spans {
                    if node == start {
                        return Role::RackLeader { members: (start + 1..end).collect() };
                    }
                    if node > start && node < end {
                        return Role::Member;
                    }
                }
                Role::Flat
            }
        }
    };
    let child_nodes: Vec<usize> = match &spans {
        None => (0..k).collect(),
        Some(spans) => spans.iter().map(|&(start, _)| start).collect(),
    };

    let (listener, _port) = bind_ephemeral()?;
    let leader_addr = listener.local_addr().map_err(|_| CommError::WorkerLost)?;

    let mut report = WireReport {
        x: x0.to_vec(),
        last_mean: vec![0.0; d],
        last_decoded: Vec::new(),
        payload_bits: 0,
        frame_bytes: 0,
        comm_s: 0.0,
        comm_exposed_s: 0.0,
        comm_hidden_s: 0.0,
        rounds: Vec::with_capacity(steps),
        node_ports: vec![0; k],
    };
    let mut leader_sent = 0u64;
    let mut dec = codec.decoder();
    let mut mean: Vec<f64> = Vec::new();
    let mut scratch: Vec<f64> = Vec::new();

    let mut worker_err: Option<CommError> = None;
    let mut worker_xs: Vec<Option<Vec<f64>>> = (0..k).map(|_| None).collect();

    let run: Result<(), CommError> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(k);
        for node in 0..k {
            let cfg = WorkerCfg {
                node,
                k,
                leader_addr,
                role: role_of(node),
                workload,
                codec,
                x0,
                steps,
                seed,
                plan,
                opts: *opts,
                update,
            };
            handles.push(scope.spawn(move || worker_main(cfg)));
        }

        let loop_result: Result<(), CommError> = (|| {
            // ---- handshake: collect every node's Hello (and its
            // OS-assigned ports), then reply with each node's parent port
            let mut conns: Vec<Option<TcpStream>> = (0..k).map(|_| None).collect();
            let mut listen_ports = vec![0u16; k];
            for _ in 0..k {
                let mut s = accept_configured(&listener, &opts.socket)?;
                match read_frame(&mut s)? {
                    (Frame::Hello { node, listen_port }, _) => {
                        let n = node as usize;
                        if n >= k || conns[n].is_some() {
                            return Err(CommError::WorkerLost);
                        }
                        listen_ports[n] = listen_port;
                        report.node_ports[n] =
                            s.peer_addr().map_err(|_| CommError::WorkerLost)?.port();
                        conns[n] = Some(s);
                    }
                    _ => return Err(CommError::WorkerLost),
                }
            }
            for node in 0..k {
                let parent_port = match role_of(node) {
                    Role::Member => match &spans {
                        Some(spans) => spans
                            .iter()
                            .find(|&&(start, end)| node > start && node < end)
                            .map(|&(start, _)| listen_ports[start])
                            .ok_or(CommError::WorkerLost)?,
                        None => return Err(CommError::WorkerLost),
                    },
                    _ => 0,
                };
                match conns[node].as_mut() {
                    Some(s) => {
                        leader_sent += write_frame(
                            s,
                            &Frame::Welcome { node: node as u32, parent_port },
                        )?;
                    }
                    None => return Err(CommError::WorkerLost),
                }
            }
            // data plane: keep only the direct children, in node order;
            // member streams were handshake-only
            let mut children: Vec<(usize, TcpStream)> = Vec::with_capacity(child_nodes.len());
            for &node in &child_nodes {
                match conns[node].take() {
                    Some(s) => children.push((node, s)),
                    None => return Err(CommError::WorkerLost),
                }
            }
            drop(conns);

            // ---- round machinery
            let hierarchical = spans.is_some();
            let recv_round = |t: usize,
                              children: &mut Vec<(usize, TcpStream)>|
             -> Result<RoundIn, CommError> {
                let mut set: Vec<Option<WirePacket>> = (0..k).map(|_| None).collect();
                let mut recv_bytes = 0u64;
                for (node, s) in children.iter_mut() {
                    let (frame, n) = read_frame(s)?;
                    recv_bytes += n;
                    match frame {
                        Frame::Packet { node: pn, round, packet }
                            if !hierarchical
                                && pn as usize == *node
                                && round == t as u64 =>
                        {
                            set[*node] = Some(packet);
                        }
                        Frame::Bundle { round, packets }
                            if hierarchical && round == t as u64 =>
                        {
                            for (pn, p) in packets {
                                let idx = pn as usize;
                                if idx >= k || set[idx].is_some() {
                                    return Err(CommError::WorkerLost);
                                }
                                set[idx] = Some(p);
                            }
                        }
                        _ => return Err(CommError::WorkerLost),
                    }
                }
                let mut payload_bits = 0u64;
                for s in set.iter() {
                    match s {
                        Some(p) => payload_bits += p.len_bits() as u64,
                        None => return Err(CommError::WorkerLost),
                    }
                }
                Ok(RoundIn { set, payload_bits, recv_bytes })
            };

            let send_round = |t: usize,
                              set: &[Option<WirePacket>],
                              children: &mut Vec<(usize, TcpStream)>|
             -> Result<u64, CommError> {
                let mut refs: Vec<(u32, &WirePacket)> = Vec::with_capacity(k);
                for (i, s) in set.iter().enumerate() {
                    match s {
                        Some(p) => refs.push((i as u32, p)),
                        None => return Err(CommError::WorkerLost),
                    }
                }
                let bytes = bundle_frame_bytes(t as u64, &refs)?;
                let mut sent = 0u64;
                for (_, s) in children.iter_mut() {
                    sent += write_all_bytes(s, &bytes)?;
                }
                Ok(sent)
            };

            let mut total_bits = 0u64;
            let mut finish_round = |t: usize,
                                    rin: RoundIn,
                                    gather_s: f64,
                                    broadcast_s: f64,
                                    sent_bytes: u64,
                                    report: &mut WireReport,
                                    dec: &mut dyn Compressor,
                                    mean: &mut Vec<f64>,
                                    scratch: &mut Vec<f64>,
                                    sinks: &mut [&mut dyn MetricsSink]|
             -> Result<(), CommError> {
                decode_aggregate_into(k, d, mean, scratch, |node, out| {
                    match rin.set[node].as_ref() {
                        Some(p) => {
                            dec.decode_into(p, out)?;
                            if t == steps {
                                report.last_decoded.push(out.clone());
                            }
                            Ok(())
                        }
                        None => Err(CommError::WorkerLost),
                    }
                })?;
                // the leader's replica applies every aggregate exactly once
                // in round order — the same fold every worker performs, so
                // the final iterates agree under both schedules
                let x = &mut report.x;
                (update)(x, mean, t);
                let comm_s = gather_s + broadcast_s;
                let (exposed, hidden) = plan.split(comm_s);
                report.comm_s += comm_s;
                report.comm_exposed_s += exposed;
                report.comm_hidden_s += hidden;
                report.payload_bits += rin.payload_bits;
                total_bits += rin.payload_bits;
                report.rounds.push(WireRoundRecord {
                    round: t,
                    gather_s,
                    broadcast_s,
                    comm_s,
                    comm_exposed_s: exposed,
                    comm_hidden_s: hidden,
                    payload_bits: rin.payload_bits,
                    frame_bytes: rin.recv_bytes + sent_bytes,
                });
                if t == steps {
                    report.last_mean.clone_from(mean);
                }
                let rec = StepRecord {
                    t,
                    stats: StepStats {
                        bits: rin.payload_bits,
                        quant_err_sq: 0.0,
                        dual_norm_sq: 0.0,
                    },
                    total_bits,
                    oracle_calls: (k * t) as u64,
                    gap: None,
                    comm_s,
                    comm_exposed_s: exposed,
                    comm_hidden_s: hidden,
                };
                for sink in sinks.iter_mut() {
                    sink.on_step(&rec);
                }
                Ok(())
            };

            match plan.mode {
                ExchangeMode::Synchronous => {
                    for t in 1..=steps {
                        let g0 = Instant::now();
                        let rin = recv_round(t, &mut children)?;
                        let gather_s = g0.elapsed().as_secs_f64();
                        let b0 = Instant::now();
                        let sent_bytes = send_round(t, &rin.set, &mut children)?;
                        leader_sent += sent_bytes;
                        let broadcast_s = b0.elapsed().as_secs_f64();
                        finish_round(
                            t,
                            rin,
                            gather_s,
                            broadcast_s,
                            sent_bytes,
                            &mut report,
                            dec.as_mut(),
                            &mut mean,
                            &mut scratch,
                            sinks,
                        )?;
                    }
                }
                ExchangeMode::Overlapped { .. } => {
                    // drain round t+1's uplink before writing round t's
                    // downlink: peers write-then-read, so the leader must
                    // read-then-write or finite socket buffers could wedge
                    // both sides mid-write
                    let mut pending: Option<RoundIn> = None;
                    for t in 1..=steps {
                        let g0 = Instant::now();
                        let rin = match pending.take() {
                            Some(r) => r,
                            None => recv_round(t, &mut children)?,
                        };
                        if t < steps {
                            pending = Some(recv_round(t + 1, &mut children)?);
                        }
                        let gather_s = g0.elapsed().as_secs_f64();
                        let b0 = Instant::now();
                        let sent_bytes = send_round(t, &rin.set, &mut children)?;
                        leader_sent += sent_bytes;
                        let broadcast_s = b0.elapsed().as_secs_f64();
                        finish_round(
                            t,
                            rin,
                            gather_s,
                            broadcast_s,
                            sent_bytes,
                            &mut report,
                            dec.as_mut(),
                            &mut mean,
                            &mut scratch,
                            sinks,
                        )?;
                    }
                }
            }
            Ok(())
        })();

        // tear the data plane down (workers unblock on EOF if we errored
        // mid-round), then collect every worker's exit
        drop(listener);
        for h in handles {
            match h.join() {
                Ok(Ok(exit)) => {
                    report.frame_bytes += exit.sent;
                    let node = worker_xs.iter().position(|w| w.is_none());
                    if let Some(i) = node {
                        worker_xs[i] = Some(exit.x);
                    }
                }
                Ok(Err(e)) => worker_err = Some(e),
                Err(_) => worker_err = Some(CommError::WorkerLost),
            }
        }
        loop_result
    });

    run?;
    if let Some(e) = worker_err {
        return Err(e);
    }
    report.frame_bytes += leader_sent;
    // replica invariant: every node ran the same fold over the same
    // decoded aggregates, so all final iterates are bit-identical
    for wx in worker_xs.iter().flatten() {
        debug_assert_eq!(wx, &report.x, "wire replicas diverged");
    }
    Ok(report)
}

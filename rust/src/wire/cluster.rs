//! The measured-wire cluster engine: leader + K workers as real OS threads
//! shipping entropy-coded [`WirePacket`] bytes over localhost TCP.
//!
//! This is the repo's third coordinator engine. The other two charge an
//! analytic clock; here `comm_s` is **measured** — a monotonic
//! [`Instant`] wraps every socket send/recv phase, and nothing in this
//! module (or anywhere under `wire/`) calls the analytic charge model.
//! The split into exposed vs hidden seconds reuses
//! [`ExchangePlan::split`], exactly the accounting `PhaseTimeline` applies
//! to modeled charges — same semantics, measured input.
//!
//! Aggregates stay bit-identical to `ClusterSim` and the threaded engine
//! *by construction*: every node decodes the full packet set through
//! [`decode_aggregate_into`] (node order, `v/k` folds) with codecs seeded
//! by the shared [`worker_codec_seed`] / [`worker_oracle_seed`] formulas —
//! there is no wire-local copy of the aggregation arithmetic.
//!
//! Round flow (flat star): every worker encodes its dual and sends a
//! round-tagged `Packet` to the leader; the leader gathers all K, then
//! multicasts the full set back down as one `Bundle`; every node decodes
//! all K packets locally and applies the same deterministic update — an
//! allgather, so the downlink carries coded bytes, not f64 iterates.
//! Hierarchical: members send to their rack leader, rack leaders forward
//! gathered bundles up, the leader multicasts the full set to rack leaders
//! only, and rack leaders fan it down — the leader's serialized egress
//! shrinks from K to R copies with the fan-out parallelized across racks,
//! which is where the measured hierarchical win at K = 12 comes from.
//!
//! Overlapped exchanges follow the threaded engine's depth-stale schedule
//! verbatim (send round t+1 before consuming round t, stage aggregates,
//! drain at the end). To keep the pipeline deadlock-free against finite
//! kernel socket buffers, the leader reads round t+1's uplink *before*
//! writing round t's downlink — every peer that could be mid-write is
//! drained before a large write heads their way.
//!
//! Sharded reduce-scatter ([`TopologySpec::ShardedReduceScatter`]): the
//! leader stays pure control plane and coded bytes move only over a full
//! worker-to-worker TCP mesh. Ownership is the static coordinate-count
//! split of [`assign_layers_by_bits`] — identical on every node, so no
//! assignment traffic (the sim engines' measured-bits rebalancing is a
//! model-side refinement; ownership never changes the aggregate). Each
//! round every node ships each owner only that owner's layer range of its
//! coded packet ([`WirePacket::shard`]), owners fold their slice through
//! the shared slice core, and bit-exact reduced f64 slices allgather back
//! over the mesh — peak per-link traffic drops toward ~1/K of the flat
//! star's. Phase seconds are measured on every node and folded by the
//! leader as max-over-nodes: a synchronous round cannot finish before its
//! slowest node does. Sync-only — ring and overlapped sharded wire
//! exchanges decline with [`CommError::Unsupported`] rather than
//! pretending an unimplemented schedule was measured.

use super::frame::{
    bundle_frame_bytes, packet_frame_bytes, read_frame, read_frame_bytes,
    shard_frame_bytes, slice_frame_bytes, write_all_bytes, write_frame, Frame,
};
use super::socket::{accept_configured, bind_ephemeral, connect_with_backoff, SocketConfig};
use crate::comm::{CommError, Compressor, IdentityCompressor, WirePacket};
use crate::coordinator::collectives::assign_layers_by_bits;
use crate::coordinator::core::{decode_aggregate_into, decode_aggregate_slice_into};
use crate::coordinator::parallel::{worker_codec_seed, worker_oracle_seed, SharedQuantState};
use crate::coordinator::topology::{rack_spans, ExchangeMode, ExchangePlan, TopologySpec};
use crate::oda::driver::{MetricsSink, StepRecord, StepStats};
use crate::stats::rng::Rng;
use crate::vi::noise::{NoiseModel, Oracle};
use crate::vi::operator::Operator;
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

/// What each worker feeds the codec every round.
#[derive(Clone, Copy)]
pub enum Workload<'a> {
    /// A VI oracle: `g = A(x) + noise`, seeded with the engines' shared
    /// per-node formula — the parity-pinned mode.
    Oracle { op: &'a dyn Operator, noise: NoiseModel },
    /// Seeded Gaussian duals of dimension `dim`, independent of `x` — the
    /// timing-bench mode, where `dim` can be paper-sized without paying a
    /// dense operator apply.
    Synthetic { dim: usize, scale: f64 },
}

impl Workload<'_> {
    pub fn dim(&self) -> usize {
        match self {
            Workload::Oracle { op, .. } => op.dim(),
            Workload::Synthetic { dim, .. } => *dim,
        }
    }
}

/// The synchronized codec every node builds locally (codebooks never travel
/// on the wire — same contract as the in-process engines).
#[derive(Clone)]
pub enum WireCodecSpec {
    /// fp32 on the wire: the uncompressed collective baseline.
    Identity,
    /// The paper's quantize + entropy-code scheme under synchronized fixed
    /// state; per-node encoder RNGs use [`worker_codec_seed`].
    Quant(SharedQuantState),
}

impl WireCodecSpec {
    fn encoder(&self, seed: u64, node: usize) -> Box<dyn Compressor> {
        match self {
            WireCodecSpec::Identity => Box::new(IdentityCompressor::new()),
            WireCodecSpec::Quant(st) => Box::new(st.codec(worker_codec_seed(seed, node))),
        }
    }

    fn decoder(&self) -> Box<dyn Compressor> {
        match self {
            WireCodecSpec::Identity => Box::new(IdentityCompressor::new()),
            // decode draws no randomness; seed 0 mirrors the threaded
            // engine's leader decoder
            WireCodecSpec::Quant(st) => Box::new(st.codec(0)),
        }
    }
}

/// Engine knobs beyond the socket layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireOptions {
    pub socket: SocketConfig,
    /// Test hook: `(node, round)` — that worker drops its connections
    /// instead of producing that round's packet, so the suite can prove a
    /// mid-round death surfaces as [`CommError::WorkerLost`] within the
    /// read timeout instead of deadlocking.
    pub kill: Option<(usize, usize)>,
}

impl WireOptions {
    pub fn with_kill(mut self, node: usize, round: usize) -> Self {
        self.kill = Some((node, round));
        self
    }
}

/// Per-round measured timing, all from the leader's monotonic clock.
#[derive(Clone, Copy, Debug)]
pub struct WireRoundRecord {
    pub round: usize,
    /// seconds the leader spent blocked in socket reads this round
    /// (under an overlapped exchange this includes the next round's
    /// drained uplink — total comm is exact, per-round attribution is the
    /// pipeline's)
    pub gather_s: f64,
    /// seconds the leader spent writing the full-set downlink
    pub broadcast_s: f64,
    /// `gather_s + broadcast_s`
    pub comm_s: f64,
    /// exposed share under the run's [`ExchangePlan`]
    pub comm_exposed_s: f64,
    /// hidden share (`comm_exposed_s + comm_hidden_s == comm_s`)
    pub comm_hidden_s: f64,
    /// sum of the K packets' exact payload bits — the same number the
    /// analytic engines charge for a flat exchange
    pub payload_bits: u64,
    /// framed bytes the leader itself moved (sent + received) this round
    pub frame_bytes: u64,
    /// most framed bytes any single link carried this round: the busiest
    /// leader-adjacent link (uplink + its share of the downlink) for the
    /// star plans, the busiest mesh link (max over nodes of their
    /// per-peer totals) for the sharded plan
    pub peak_link_bytes: f64,
}

/// What a measured wire run produced.
#[derive(Clone, Debug)]
pub struct WireReport {
    /// final iterate (the leader's replica; every worker's copy is
    /// debug-asserted identical)
    pub x: Vec<f64>,
    /// mean decoded vector of the last round
    pub last_mean: Vec<f64>,
    /// each node's decoded dual of the last round (parity pinning; filled
    /// by the star plans only — under the sharded mesh no single node
    /// decodes every full packet, so this stays empty)
    pub last_decoded: Vec<Vec<f64>>,
    /// total payload bits across rounds (flat accounting: each packet
    /// counted once — comparable to `ClusterSim`'s flat `wire_bits`)
    pub payload_bits: u64,
    /// total framed bytes sent across every socket by every thread
    pub frame_bytes: u64,
    /// total measured comm seconds (leader clock)
    pub comm_s: f64,
    pub comm_exposed_s: f64,
    pub comm_hidden_s: f64,
    /// hottest single link of the run (max over rounds of the per-round
    /// [`WireRoundRecord::peak_link_bytes`])
    pub peak_link_bytes: f64,
    /// per-round measured records
    pub rounds: Vec<WireRoundRecord>,
    /// each node's OS-assigned ephemeral source port, collected during the
    /// handshake (no fixed ports anywhere)
    pub node_ports: Vec<u16>,
}

/// A node's role in the physical star, derived from the run's topology.
#[derive(Clone, Debug)]
enum Role {
    /// talks straight to the leader (flat and parameter-server plans)
    Flat,
    /// talks to the leader and relays for `members`
    RackLeader { members: Vec<usize> },
    /// talks to its rack leader (port learned via the handshake)
    Member,
}

struct WorkerExit {
    x: Vec<f64>,
    sent: u64,
}

enum WorkerSource<'a> {
    Oracle(Oracle<'a>),
    Synthetic { rng: Rng, dim: usize, scale: f64 },
}

impl<'a> WorkerSource<'a> {
    fn new(w: &Workload<'a>, seed: u64, node: usize) -> WorkerSource<'a> {
        match *w {
            Workload::Oracle { op, noise } => {
                WorkerSource::Oracle(Oracle::new(op, noise, worker_oracle_seed(seed, node)))
            }
            Workload::Synthetic { dim, scale } => WorkerSource::Synthetic {
                rng: Rng::new(worker_oracle_seed(seed, node)),
                dim,
                scale,
            },
        }
    }

    fn sample(&mut self, x: &[f64]) -> Vec<f64> {
        match self {
            WorkerSource::Oracle(o) => o.sample(x),
            WorkerSource::Synthetic { rng, dim, scale } => {
                (0..*dim).map(|_| *scale * rng.gaussian()).collect()
            }
        }
    }
}

/// Receive one round-tagged packet from every member, in member order
/// (per-socket FIFO means the first unread frame is always the expected
/// round; a mismatch is a protocol break — the peer counts as lost).
fn recv_member_packets(
    members: &mut [(usize, TcpStream)],
    round: usize,
) -> Result<Vec<(u32, WirePacket)>, CommError> {
    let mut out = Vec::with_capacity(members.len());
    for (node, s) in members.iter_mut() {
        match read_frame(s)? {
            (Frame::Packet { node: n, round: r, packet }, _)
                if n as usize == *node && r == round as u64 =>
            {
                out.push((n, packet))
            }
            _ => return Err(CommError::WorkerLost),
        }
    }
    Ok(out)
}

/// Receive the full-set bundle for `round` from the parent, returning the
/// node-indexed set plus the raw frame bytes (for verbatim fan-down).
fn recv_full_set(
    parent: &mut TcpStream,
    round: usize,
    k: usize,
) -> Result<(Vec<Option<WirePacket>>, Vec<u8>), CommError> {
    let (frame, raw) = read_frame_bytes(parent)?;
    match frame {
        Frame::Bundle { round: r, packets } if r == round as u64 => {
            let mut set: Vec<Option<WirePacket>> = (0..k).map(|_| None).collect();
            for (n, p) in packets {
                let idx = n as usize;
                if idx >= k || set[idx].is_some() {
                    return Err(CommError::WorkerLost);
                }
                set[idx] = Some(p);
            }
            if set.iter().any(|s| s.is_none()) {
                return Err(CommError::WorkerLost);
            }
            Ok((set, raw))
        }
        _ => Err(CommError::WorkerLost),
    }
}

/// Decode all K packets in node order through the shared aggregate core.
fn aggregate_set(
    set: &[Option<WirePacket>],
    dec: &mut dyn Compressor,
    k: usize,
    d: usize,
    mean: &mut Vec<f64>,
    scratch: &mut Vec<f64>,
) -> Result<(), CommError> {
    decode_aggregate_into(k, d, mean, scratch, |node, out| match set[node].as_ref() {
        Some(p) => dec.decode_into(p, out),
        None => Err(CommError::WorkerLost),
    })
}

/// Encode this node's round-`t` dual at `x` and ship it up: a `Packet`
/// frame for plain workers, a gathered `Bundle` (own + members, node
/// order) for rack leaders. Returns `Ok(false)` when the kill hook fired.
#[allow(clippy::too_many_arguments)]
fn send_up(
    t: usize,
    node: usize,
    is_rack_leader: bool,
    members: &mut [(usize, TcpStream)],
    parent: &mut TcpStream,
    source: &mut WorkerSource<'_>,
    enc: &mut dyn Compressor,
    own: &mut WirePacket,
    x: &[f64],
    kill: Option<(usize, usize)>,
    sent: &mut u64,
) -> Result<bool, CommError> {
    if kill == Some((node, t)) {
        return Ok(false);
    }
    let dual = source.sample(x);
    enc.encode_into(&dual, own)?;
    if is_rack_leader {
        let kids = recv_member_packets(members, t)?;
        let mut refs: Vec<(u32, &WirePacket)> = Vec::with_capacity(1 + kids.len());
        refs.push((node as u32, own));
        for (n, p) in &kids {
            refs.push((*n, p));
        }
        refs.sort_by_key(|(n, _)| *n);
        let bytes = bundle_frame_bytes(t as u64, &refs)?;
        *sent += write_all_bytes(parent, &bytes)?;
    } else {
        let bytes = packet_frame_bytes(node as u32, t as u64, own)?;
        *sent += write_all_bytes(parent, &bytes)?;
    }
    Ok(true)
}

struct WorkerCfg<'a> {
    node: usize,
    k: usize,
    leader_addr: SocketAddr,
    role: Role,
    workload: Workload<'a>,
    codec: &'a WireCodecSpec,
    x0: &'a [f64],
    steps: usize,
    seed: u64,
    plan: ExchangePlan,
    opts: WireOptions,
    update: &'a (dyn Fn(&mut Vec<f64>, &[f64], usize) + Sync),
}

fn worker_main(cfg: WorkerCfg<'_>) -> Result<WorkerExit, CommError> {
    let d = cfg.workload.dim();
    let sock = cfg.opts.socket;
    let is_rack_leader = matches!(cfg.role, Role::RackLeader { .. });

    // rack leaders bind their member-facing listener *before* dialing in
    // so the OS-assigned port rides in the Hello
    let listener = match &cfg.role {
        Role::RackLeader { members } if !members.is_empty() => Some(bind_ephemeral()?),
        _ => None,
    };
    let listen_port = listener.as_ref().map_or(0, |(_, p)| *p);

    let mut leader = connect_with_backoff(cfg.leader_addr, &sock)?;
    let mut sent = 0u64;
    sent += write_frame(&mut leader, &Frame::Hello { node: cfg.node as u32, listen_port })?;
    let parent_port = match read_frame(&mut leader)? {
        (Frame::Welcome { node, parent_port }, _) if node as usize == cfg.node => parent_port,
        _ => return Err(CommError::WorkerLost),
    };

    // establish the data plane
    let mut parent: TcpStream;
    let mut members: Vec<(usize, TcpStream)> = Vec::new();
    match &cfg.role {
        Role::Member => {
            // the leader stream was handshake-only; rounds go via the rack
            // leader's collected port
            let addr: SocketAddr = ([127, 0, 0, 1], parent_port).into();
            parent = connect_with_backoff(addr, &sock)?;
            sent += write_frame(
                &mut parent,
                &Frame::Hello { node: cfg.node as u32, listen_port: 0 },
            )?;
            drop(leader);
        }
        Role::Flat => parent = leader,
        Role::RackLeader { members: want } => {
            parent = leader;
            if let Some((l, _)) = &listener {
                for _ in 0..want.len() {
                    let mut s = accept_configured(l, &sock)?;
                    let who = match read_frame(&mut s)? {
                        (Frame::Hello { node, .. }, _) => node as usize,
                        _ => return Err(CommError::WorkerLost),
                    };
                    if !want.contains(&who) {
                        return Err(CommError::WorkerLost);
                    }
                    members.push((who, s));
                }
                members.sort_by_key(|(n, _)| *n);
            }
        }
    }
    drop(listener);

    let mut enc = cfg.codec.encoder(cfg.seed, cfg.node);
    let mut dec = cfg.codec.decoder();
    let mut source = WorkerSource::new(&cfg.workload, cfg.seed, cfg.node);
    let mut x = cfg.x0.to_vec();
    let mut own = WirePacket::new();
    let mut mean: Vec<f64> = Vec::new();
    let mut scratch: Vec<f64> = Vec::new();
    let kill = cfg.opts.kill;
    let update = cfg.update;

    match cfg.plan.mode {
        ExchangeMode::Synchronous => {
            for t in 1..=cfg.steps {
                if !send_up(
                    t,
                    cfg.node,
                    is_rack_leader,
                    &mut members,
                    &mut parent,
                    &mut source,
                    enc.as_mut(),
                    &mut own,
                    &x,
                    kill,
                    &mut sent,
                )? {
                    return Ok(WorkerExit { x, sent });
                }
                let (set, raw) = recv_full_set(&mut parent, t, cfg.k)?;
                for (_, s) in members.iter_mut() {
                    sent += write_all_bytes(s, &raw)?;
                }
                aggregate_set(&set, dec.as_mut(), cfg.k, d, &mut mean, &mut scratch)?;
                update(&mut x, &mean, t);
            }
        }
        ExchangeMode::Overlapped { depth } => {
            let depth = depth.max(1);
            // aggregates decoded but not yet applied: the node-side double
            // buffer, identical to the threaded engine's schedule
            let mut staged: VecDeque<(usize, Vec<f64>)> = VecDeque::new();
            if cfg.steps > 0
                && !send_up(
                    1,
                    cfg.node,
                    is_rack_leader,
                    &mut members,
                    &mut parent,
                    &mut source,
                    enc.as_mut(),
                    &mut own,
                    &x,
                    kill,
                    &mut sent,
                )?
            {
                return Ok(WorkerExit { x, sent });
            }
            for t in 1..=cfg.steps {
                if t < cfg.steps {
                    if staged.front().map_or(false, |&(r, _)| r + depth <= t) {
                        if let Some((r, m)) = staged.pop_front() {
                            update(&mut x, &m, r);
                        }
                    }
                    // round t+1 goes up *before* round t's downlink is
                    // consumed — this is the genuine overlap, and the
                    // leader drains it before writing the big bundle
                    if !send_up(
                        t + 1,
                        cfg.node,
                        is_rack_leader,
                        &mut members,
                        &mut parent,
                        &mut source,
                        enc.as_mut(),
                        &mut own,
                        &x,
                        kill,
                        &mut sent,
                    )? {
                        return Ok(WorkerExit { x, sent });
                    }
                }
                let (set, raw) = recv_full_set(&mut parent, t, cfg.k)?;
                for (_, s) in members.iter_mut() {
                    sent += write_all_bytes(s, &raw)?;
                }
                aggregate_set(&set, dec.as_mut(), cfg.k, d, &mut mean, &mut scratch)?;
                staged.push_back((t, mean.clone()));
            }
            while let Some((r, m)) = staged.pop_front() {
                update(&mut x, &m, r);
            }
        }
    }
    Ok(WorkerExit { x, sent })
}

/// One round's gathered uplink at the leader.
struct RoundIn {
    set: Vec<Option<WirePacket>>,
    payload_bits: u64,
    recv_bytes: u64,
    /// most framed bytes read off any single child link this round
    max_link_recv: u64,
}

/// Run a measured wire exchange: `steps` rounds over real localhost TCP
/// with `k` worker threads, each node holding an identical iterate replica
/// advanced by `update`. See the module docs for the round flow; see
/// [`run_wire_observed`] for streaming per-round records to sinks.
#[allow(clippy::too_many_arguments)]
pub fn run_wire(
    workload: Workload<'_>,
    k: usize,
    codec: &WireCodecSpec,
    x0: &[f64],
    steps: usize,
    seed: u64,
    topology: &TopologySpec,
    plan: ExchangePlan,
    opts: &WireOptions,
    update: &(dyn Fn(&mut Vec<f64>, &[f64], usize) + Sync),
) -> Result<WireReport, CommError> {
    run_wire_observed(
        workload, k, codec, x0, steps, seed, topology, plan, opts, update, &mut [],
    )
}

/// [`run_wire`] with live [`MetricsSink`] streaming: every round emits a
/// [`StepRecord`] whose `comm_s` / exposed / hidden fields are *measured*
/// seconds from the leader's monotonic clock.
#[allow(clippy::too_many_arguments)]
pub fn run_wire_observed(
    workload: Workload<'_>,
    k: usize,
    codec: &WireCodecSpec,
    x0: &[f64],
    steps: usize,
    seed: u64,
    topology: &TopologySpec,
    plan: ExchangePlan,
    opts: &WireOptions,
    update: &(dyn Fn(&mut Vec<f64>, &[f64], usize) + Sync),
    sinks: &mut [&mut dyn MetricsSink],
) -> Result<WireReport, CommError> {
    let d = workload.dim();
    assert!(k >= 1, "a wire run needs at least one worker");
    assert_eq!(x0.len(), d, "x0 dimension must match the workload");

    match topology {
        // a measured ring schedule is future work — decline rather than
        // silently run a different wire plan than the caller asked for
        TopologySpec::Ring => {
            return Err(CommError::Unsupported { what: "ring wire exchange" });
        }
        TopologySpec::ShardedReduceScatter => {
            if matches!(plan.mode, ExchangeMode::Overlapped { .. }) {
                return Err(CommError::Unsupported {
                    what: "overlapped sharded wire exchange",
                });
            }
            return run_wire_sharded(
                workload, k, codec, x0, steps, seed, plan, opts, update, sinks,
            );
        }
        _ => {}
    }

    // the physical plan: contiguous rack spans for hierarchical runs, the
    // plain star otherwise (parameter-server already *is* a star)
    let spans: Option<Vec<(usize, usize)>> = match topology {
        TopologySpec::Hierarchical { racks } => Some(rack_spans(k, *racks)),
        _ => None,
    };
    let role_of = |node: usize| -> Role {
        match &spans {
            None => Role::Flat,
            Some(spans) => {
                for &(start, end) in spans {
                    if node == start {
                        return Role::RackLeader { members: (start + 1..end).collect() };
                    }
                    if node > start && node < end {
                        return Role::Member;
                    }
                }
                Role::Flat
            }
        }
    };
    let child_nodes: Vec<usize> = match &spans {
        None => (0..k).collect(),
        Some(spans) => spans.iter().map(|&(start, _)| start).collect(),
    };

    let (listener, _port) = bind_ephemeral()?;
    let leader_addr = listener.local_addr().map_err(|_| CommError::WorkerLost)?;

    let mut report = WireReport {
        x: x0.to_vec(),
        last_mean: vec![0.0; d],
        last_decoded: Vec::new(),
        payload_bits: 0,
        frame_bytes: 0,
        comm_s: 0.0,
        comm_exposed_s: 0.0,
        comm_hidden_s: 0.0,
        peak_link_bytes: 0.0,
        rounds: Vec::with_capacity(steps),
        node_ports: vec![0; k],
    };
    let mut leader_sent = 0u64;
    let mut dec = codec.decoder();
    let mut mean: Vec<f64> = Vec::new();
    let mut scratch: Vec<f64> = Vec::new();

    let mut worker_err: Option<CommError> = None;
    let mut worker_xs: Vec<Option<Vec<f64>>> = (0..k).map(|_| None).collect();

    let run: Result<(), CommError> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(k);
        for node in 0..k {
            let cfg = WorkerCfg {
                node,
                k,
                leader_addr,
                role: role_of(node),
                workload,
                codec,
                x0,
                steps,
                seed,
                plan,
                opts: *opts,
                update,
            };
            handles.push(scope.spawn(move || worker_main(cfg)));
        }

        let loop_result: Result<(), CommError> = (|| {
            // ---- handshake: collect every node's Hello (and its
            // OS-assigned ports), then reply with each node's parent port
            let mut conns: Vec<Option<TcpStream>> = (0..k).map(|_| None).collect();
            let mut listen_ports = vec![0u16; k];
            for _ in 0..k {
                let mut s = accept_configured(&listener, &opts.socket)?;
                match read_frame(&mut s)? {
                    (Frame::Hello { node, listen_port }, _) => {
                        let n = node as usize;
                        if n >= k || conns[n].is_some() {
                            return Err(CommError::WorkerLost);
                        }
                        listen_ports[n] = listen_port;
                        report.node_ports[n] =
                            s.peer_addr().map_err(|_| CommError::WorkerLost)?.port();
                        conns[n] = Some(s);
                    }
                    _ => return Err(CommError::WorkerLost),
                }
            }
            for node in 0..k {
                let parent_port = match role_of(node) {
                    Role::Member => match &spans {
                        Some(spans) => spans
                            .iter()
                            .find(|&&(start, end)| node > start && node < end)
                            .map(|&(start, _)| listen_ports[start])
                            .ok_or(CommError::WorkerLost)?,
                        None => return Err(CommError::WorkerLost),
                    },
                    _ => 0,
                };
                match conns[node].as_mut() {
                    Some(s) => {
                        leader_sent += write_frame(
                            s,
                            &Frame::Welcome { node: node as u32, parent_port },
                        )?;
                    }
                    None => return Err(CommError::WorkerLost),
                }
            }
            // data plane: keep only the direct children, in node order;
            // member streams were handshake-only
            let mut children: Vec<(usize, TcpStream)> = Vec::with_capacity(child_nodes.len());
            for &node in &child_nodes {
                match conns[node].take() {
                    Some(s) => children.push((node, s)),
                    None => return Err(CommError::WorkerLost),
                }
            }
            drop(conns);

            // ---- round machinery
            let hierarchical = spans.is_some();
            let recv_round = |t: usize,
                              children: &mut Vec<(usize, TcpStream)>|
             -> Result<RoundIn, CommError> {
                let mut set: Vec<Option<WirePacket>> = (0..k).map(|_| None).collect();
                let mut recv_bytes = 0u64;
                let mut max_link_recv = 0u64;
                for (node, s) in children.iter_mut() {
                    let (frame, n) = read_frame(s)?;
                    recv_bytes += n;
                    max_link_recv = max_link_recv.max(n);
                    match frame {
                        Frame::Packet { node: pn, round, packet }
                            if !hierarchical
                                && pn as usize == *node
                                && round == t as u64 =>
                        {
                            set[*node] = Some(packet);
                        }
                        Frame::Bundle { round, packets }
                            if hierarchical && round == t as u64 =>
                        {
                            for (pn, p) in packets {
                                let idx = pn as usize;
                                if idx >= k || set[idx].is_some() {
                                    return Err(CommError::WorkerLost);
                                }
                                set[idx] = Some(p);
                            }
                        }
                        _ => return Err(CommError::WorkerLost),
                    }
                }
                let mut payload_bits = 0u64;
                for s in set.iter() {
                    match s {
                        Some(p) => payload_bits += p.len_bits() as u64,
                        None => return Err(CommError::WorkerLost),
                    }
                }
                Ok(RoundIn { set, payload_bits, recv_bytes, max_link_recv })
            };

            let send_round = |t: usize,
                              set: &[Option<WirePacket>],
                              children: &mut Vec<(usize, TcpStream)>|
             -> Result<u64, CommError> {
                let mut refs: Vec<(u32, &WirePacket)> = Vec::with_capacity(k);
                for (i, s) in set.iter().enumerate() {
                    match s {
                        Some(p) => refs.push((i as u32, p)),
                        None => return Err(CommError::WorkerLost),
                    }
                }
                let bytes = bundle_frame_bytes(t as u64, &refs)?;
                let mut sent = 0u64;
                for (_, s) in children.iter_mut() {
                    sent += write_all_bytes(s, &bytes)?;
                }
                Ok(sent)
            };

            let mut total_bits = 0u64;
            let mut finish_round = |t: usize,
                                    rin: RoundIn,
                                    gather_s: f64,
                                    broadcast_s: f64,
                                    sent_bytes: u64,
                                    peak_link_bytes: f64,
                                    report: &mut WireReport,
                                    dec: &mut dyn Compressor,
                                    mean: &mut Vec<f64>,
                                    scratch: &mut Vec<f64>,
                                    sinks: &mut [&mut dyn MetricsSink]|
             -> Result<(), CommError> {
                decode_aggregate_into(k, d, mean, scratch, |node, out| {
                    match rin.set[node].as_ref() {
                        Some(p) => {
                            dec.decode_into(p, out)?;
                            if t == steps {
                                report.last_decoded.push(out.clone());
                            }
                            Ok(())
                        }
                        None => Err(CommError::WorkerLost),
                    }
                })?;
                // the leader's replica applies every aggregate exactly once
                // in round order — the same fold every worker performs, so
                // the final iterates agree under both schedules
                let x = &mut report.x;
                (update)(x, mean, t);
                let comm_s = gather_s + broadcast_s;
                let (exposed, hidden) = plan.split(comm_s);
                report.comm_s += comm_s;
                report.comm_exposed_s += exposed;
                report.comm_hidden_s += hidden;
                report.payload_bits += rin.payload_bits;
                report.peak_link_bytes = report.peak_link_bytes.max(peak_link_bytes);
                total_bits += rin.payload_bits;
                report.rounds.push(WireRoundRecord {
                    round: t,
                    gather_s,
                    broadcast_s,
                    comm_s,
                    comm_exposed_s: exposed,
                    comm_hidden_s: hidden,
                    payload_bits: rin.payload_bits,
                    frame_bytes: rin.recv_bytes + sent_bytes,
                    peak_link_bytes,
                });
                if t == steps {
                    report.last_mean.clone_from(mean);
                }
                let rec = StepRecord {
                    t,
                    stats: StepStats {
                        bits: rin.payload_bits,
                        quant_err_sq: 0.0,
                        dual_norm_sq: 0.0,
                    },
                    total_bits,
                    oracle_calls: (k * t) as u64,
                    gap: None,
                    comm_s,
                    comm_exposed_s: exposed,
                    comm_hidden_s: hidden,
                    peak_link_bytes,
                };
                for sink in sinks.iter_mut() {
                    sink.on_step(&rec);
                }
                Ok(())
            };

            match plan.mode {
                ExchangeMode::Synchronous => {
                    for t in 1..=steps {
                        let g0 = Instant::now();
                        let rin = recv_round(t, &mut children)?;
                        let gather_s = g0.elapsed().as_secs_f64();
                        let b0 = Instant::now();
                        let sent_bytes = send_round(t, &rin.set, &mut children)?;
                        leader_sent += sent_bytes;
                        let broadcast_s = b0.elapsed().as_secs_f64();
                        // busiest leader-adjacent link: the fattest uplink
                        // plus that child's share of the fanned-out downlink
                        let peak = rin.max_link_recv as f64
                            + sent_bytes as f64 / children.len() as f64;
                        finish_round(
                            t,
                            rin,
                            gather_s,
                            broadcast_s,
                            sent_bytes,
                            peak,
                            &mut report,
                            dec.as_mut(),
                            &mut mean,
                            &mut scratch,
                            sinks,
                        )?;
                    }
                }
                ExchangeMode::Overlapped { .. } => {
                    // drain round t+1's uplink before writing round t's
                    // downlink: peers write-then-read, so the leader must
                    // read-then-write or finite socket buffers could wedge
                    // both sides mid-write
                    let mut pending: Option<RoundIn> = None;
                    for t in 1..=steps {
                        let g0 = Instant::now();
                        let rin = match pending.take() {
                            Some(r) => r,
                            None => recv_round(t, &mut children)?,
                        };
                        if t < steps {
                            pending = Some(recv_round(t + 1, &mut children)?);
                        }
                        let gather_s = g0.elapsed().as_secs_f64();
                        let b0 = Instant::now();
                        let sent_bytes = send_round(t, &rin.set, &mut children)?;
                        leader_sent += sent_bytes;
                        let broadcast_s = b0.elapsed().as_secs_f64();
                        let peak = rin.max_link_recv as f64
                            + sent_bytes as f64 / children.len() as f64;
                        finish_round(
                            t,
                            rin,
                            gather_s,
                            broadcast_s,
                            sent_bytes,
                            peak,
                            &mut report,
                            dec.as_mut(),
                            &mut mean,
                            &mut scratch,
                            sinks,
                        )?;
                    }
                }
            }
            Ok(())
        })();

        // tear the data plane down (workers unblock on EOF if we errored
        // mid-round), then collect every worker's exit
        drop(listener);
        for h in handles {
            match h.join() {
                Ok(Ok(exit)) => {
                    report.frame_bytes += exit.sent;
                    let node = worker_xs.iter().position(|w| w.is_none());
                    if let Some(i) = node {
                        worker_xs[i] = Some(exit.x);
                    }
                }
                Ok(Err(e)) => worker_err = Some(e),
                Err(_) => worker_err = Some(CommError::WorkerLost),
            }
        }
        loop_result
    });

    run?;
    if let Some(e) = worker_err {
        return Err(e);
    }
    report.frame_bytes += leader_sent;
    // replica invariant: every node ran the same fold over the same
    // decoded aggregates, so all final iterates are bit-identical
    for wx in worker_xs.iter().flatten() {
        debug_assert_eq!(wx, &report.x, "wire replicas diverged");
    }
    Ok(report)
}

// ---------------------------------------------------------------------------
// Sharded reduce-scatter mesh
// ---------------------------------------------------------------------------

/// Static owner plan for the sharded mesh: owner → contiguous layer range
/// plus the matching coordinate window, derived from the codec's layer
/// map (identity frames the whole vector as one layer). Every node
/// computes the identical plan locally, so ownership never travels on the
/// wire and cannot perturb the aggregate.
struct ShardPlan {
    /// owner → `[start, end)` layer range (may be empty when K exceeds
    /// the layer count)
    ranges: Vec<std::ops::Range<usize>>,
    /// owner → first coordinate of its slice
    coord_lo: Vec<usize>,
    /// owner → coordinates in its slice
    coord_len: Vec<usize>,
}

fn shard_plan(codec: &WireCodecSpec, d: usize, k: usize) -> ShardPlan {
    // static basis: per-layer coordinate counts — round-invariant, unlike
    // the sim engines' measured-bits rebalancing (a model-side refinement)
    let lens: Vec<u64> = match codec {
        WireCodecSpec::Identity => vec![d as u64],
        WireCodecSpec::Quant(st) => st.map.layers.iter().map(|l| l.len as u64).collect(),
    };
    let assign = assign_layers_by_bits(&lens, k);
    let mut offsets = Vec::with_capacity(lens.len() + 1);
    let mut acc = 0usize;
    for &l in &lens {
        offsets.push(acc);
        acc += l as usize;
    }
    offsets.push(acc);
    let mut ranges = Vec::with_capacity(k);
    let mut coord_lo = Vec::with_capacity(k);
    let mut coord_len = Vec::with_capacity(k);
    for &(start, end) in &assign {
        ranges.push(start..end);
        coord_lo.push(offsets[start]);
        coord_len.push(offsets[end] - offsets[start]);
    }
    ShardPlan { ranges, coord_lo, coord_len }
}

/// One node of the sharded mesh. Control plane: Hello/Welcome/Peers with
/// the leader, then one [`Frame::ShardReport`] per round. Data plane: a
/// full TCP mesh — this node dials every lower-numbered peer and accepts
/// every higher-numbered one. Every listener is bound before any node's
/// `Hello` goes up, and the leader releases the port table only after all
/// K handshakes, so mesh dials always land in a live accept backlog.
fn sharded_worker_main(cfg: WorkerCfg<'_>, plan: &ShardPlan) -> Result<WorkerExit, CommError> {
    let d = cfg.workload.dim();
    let k = cfg.k;
    let node = cfg.node;
    let sock = cfg.opts.socket;

    let (listener, listen_port) = if node + 1 < k {
        let (l, p) = bind_ephemeral()?;
        (Some(l), p)
    } else {
        (None, 0)
    };

    let mut leader = connect_with_backoff(cfg.leader_addr, &sock)?;
    let mut sent = 0u64;
    sent += write_frame(&mut leader, &Frame::Hello { node: node as u32, listen_port })?;
    match read_frame(&mut leader)? {
        (Frame::Welcome { node: n, .. }, _) if n as usize == node => {}
        _ => return Err(CommError::WorkerLost),
    }
    let ports = match read_frame(&mut leader)? {
        (Frame::Peers { ports }, _) if ports.len() == k => ports,
        _ => return Err(CommError::WorkerLost),
    };

    // mesh bring-up: dial down, accept up
    let mut peers: Vec<Option<TcpStream>> = (0..k).map(|_| None).collect();
    for (j, port) in ports.iter().enumerate().take(node) {
        let addr: SocketAddr = ([127, 0, 0, 1], *port).into();
        let mut s = connect_with_backoff(addr, &sock)?;
        sent += write_frame(&mut s, &Frame::Hello { node: node as u32, listen_port: 0 })?;
        peers[j] = Some(s);
    }
    if let Some(l) = &listener {
        for _ in node + 1..k {
            let mut s = accept_configured(l, &sock)?;
            let who = match read_frame(&mut s)? {
                (Frame::Hello { node: n, .. }, _) => n as usize,
                _ => return Err(CommError::WorkerLost),
            };
            if who <= node || who >= k || peers[who].is_some() {
                return Err(CommError::WorkerLost);
            }
            peers[who] = Some(s);
        }
    }
    drop(listener);

    let mut enc = cfg.codec.encoder(cfg.seed, node);
    let mut dec = cfg.codec.decoder();
    let mut source = WorkerSource::new(&cfg.workload, cfg.seed, node);
    let mut x = cfg.x0.to_vec();
    let mut own = WirePacket::new();
    let mut mean = vec![0.0f64; d];
    let mut slice_mean: Vec<f64> = Vec::new();
    let mut scratch: Vec<f64> = Vec::new();
    let own_range = plan.ranges[node].clone();
    let own_lo = plan.coord_lo[node];
    let own_dim = plan.coord_len[node];
    let update = cfg.update;

    for t in 1..=cfg.steps {
        if cfg.opts.kill == Some((node, t)) {
            return Ok(WorkerExit { x, sent });
        }
        let dual = source.sample(&x);
        enc.encode_into(&dual, &mut own)?;
        let payload_bits = own.len_bits() as u64;
        // framed bytes this node pushed or pulled over each mesh link
        let mut link_bytes = vec![0u64; k];

        // phase 1 (timed): ship each owner its layer range of the coded
        // packet, collect every peer's shard of this node's slice.
        // Write-all-then-read-all per phase: per-link frames stay FIFO
        // (shard before slice within a round) and payloads are far below
        // kernel socket buffering, with read timeouts as the backstop.
        let t0 = Instant::now();
        let mut shards: Vec<Option<WirePacket>> = (0..k).map(|_| None).collect();
        for o in 0..k {
            let shard = own.shard(plan.ranges[o].clone(), plan.coord_len[o])?;
            if o == node {
                shards[o] = Some(shard);
                continue;
            }
            let bytes = shard_frame_bytes(node as u32, t as u64, &shard)?;
            let s = peers[o].as_mut().ok_or(CommError::WorkerLost)?;
            let n = write_all_bytes(s, &bytes)?;
            sent += n;
            link_bytes[o] += n;
        }
        for (o, slot) in peers.iter_mut().enumerate() {
            let s = match slot {
                Some(s) => s,
                None => continue,
            };
            let (frame, n) = read_frame(s)?;
            link_bytes[o] += n;
            match frame {
                Frame::Shard { node: pn, round, packet }
                    if pn as usize == o && round == t as u64 =>
                {
                    shards[o] = Some(packet);
                }
                _ => return Err(CommError::WorkerLost),
            }
        }
        let shard_s = t0.elapsed().as_secs_f64();

        // untimed fold of the owned slice — mirrors the star plans, whose
        // leader decode also lives outside the measured socket windows
        if own_dim > 0 {
            decode_aggregate_slice_into(k, own_dim, &mut slice_mean, &mut scratch, |i, out| {
                match shards[i].as_ref() {
                    Some(p) => dec.decode_layers_into(p, own_range.clone(), out),
                    None => Err(CommError::WorkerLost),
                }
            })?;
        } else {
            slice_mean.clear();
        }
        mean[own_lo..own_lo + own_dim].copy_from_slice(&slice_mean);

        // phase 2 (timed): allgather the reduced slices as exact f64 bits
        let t1 = Instant::now();
        let bytes =
            slice_frame_bytes(node as u32, t as u64, own_lo as u64, &slice_mean)?;
        for (o, slot) in peers.iter_mut().enumerate() {
            let s = match slot {
                Some(s) => s,
                None => continue,
            };
            let n = write_all_bytes(s, &bytes)?;
            sent += n;
            link_bytes[o] += n;
        }
        for (o, slot) in peers.iter_mut().enumerate() {
            let s = match slot {
                Some(s) => s,
                None => continue,
            };
            let (frame, n) = read_frame(s)?;
            link_bytes[o] += n;
            match frame {
                Frame::Slice { node: pn, round, lo, values }
                    if pn as usize == o
                        && round == t as u64
                        && lo as usize == plan.coord_lo[o]
                        && values.len() == plan.coord_len[o] =>
                {
                    mean[plan.coord_lo[o]..plan.coord_lo[o] + values.len()]
                        .copy_from_slice(&values);
                }
                _ => return Err(CommError::WorkerLost),
            }
        }
        let slice_s = t1.elapsed().as_secs_f64();

        update(&mut x, &mean, t);
        let max_link = link_bytes.iter().fold(0u64, |a, &b| a.max(b));
        sent += write_frame(
            &mut leader,
            &Frame::ShardReport {
                node: node as u32,
                round: t as u64,
                payload_bits,
                comm_shard_s: shard_s,
                comm_slice_s: slice_s,
                max_link_bytes: max_link,
                mean: if node == 0 { mean.clone() } else { Vec::new() },
            },
        )?;
    }
    Ok(WorkerExit { x, sent })
}

/// The sharded-mesh driver behind [`run_wire_observed`] for
/// [`TopologySpec::ShardedReduceScatter`]. The leader is pure control
/// plane: after the handshake it only collects one `ShardReport` per node
/// per round. `gather_s` is the slowest node's measured shard-exchange
/// phase and `broadcast_s` the slowest slice-allgather phase — a
/// synchronous round cannot finish before its slowest node, so the
/// max-over-nodes fold is the round's wall time. `payload_bits` sums each
/// node's *full* coded packet (the flat-comparable accounting); the
/// per-link win shows up in `peak_link_bytes`, not in total bits.
#[allow(clippy::too_many_arguments)]
fn run_wire_sharded(
    workload: Workload<'_>,
    k: usize,
    codec: &WireCodecSpec,
    x0: &[f64],
    steps: usize,
    seed: u64,
    plan: ExchangePlan,
    opts: &WireOptions,
    update: &(dyn Fn(&mut Vec<f64>, &[f64], usize) + Sync),
    sinks: &mut [&mut dyn MetricsSink],
) -> Result<WireReport, CommError> {
    let d = workload.dim();
    let shard = shard_plan(codec, d, k);
    let (listener, _port) = bind_ephemeral()?;
    let leader_addr = listener.local_addr().map_err(|_| CommError::WorkerLost)?;

    let mut report = WireReport {
        x: x0.to_vec(),
        last_mean: vec![0.0; d],
        last_decoded: Vec::new(),
        payload_bits: 0,
        frame_bytes: 0,
        comm_s: 0.0,
        comm_exposed_s: 0.0,
        comm_hidden_s: 0.0,
        peak_link_bytes: 0.0,
        rounds: Vec::with_capacity(steps),
        node_ports: vec![0; k],
    };
    let mut leader_sent = 0u64;
    let mut worker_err: Option<CommError> = None;
    let mut worker_xs: Vec<Option<Vec<f64>>> = (0..k).map(|_| None).collect();

    let run: Result<(), CommError> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(k);
        for node in 0..k {
            let cfg = WorkerCfg {
                node,
                k,
                leader_addr,
                role: Role::Flat,
                workload,
                codec,
                x0,
                steps,
                seed,
                plan,
                opts: *opts,
                update,
            };
            let shard = &shard;
            handles.push(scope.spawn(move || sharded_worker_main(cfg, shard)));
        }

        let loop_result: Result<(), CommError> = (|| {
            // handshake: Hellos up, then Welcome + the mesh port table down
            // (released only once every listener is known to be bound)
            let mut conns: Vec<Option<TcpStream>> = (0..k).map(|_| None).collect();
            let mut listen_ports = vec![0u16; k];
            for _ in 0..k {
                let mut s = accept_configured(&listener, &opts.socket)?;
                match read_frame(&mut s)? {
                    (Frame::Hello { node, listen_port }, _) => {
                        let n = node as usize;
                        if n >= k || conns[n].is_some() {
                            return Err(CommError::WorkerLost);
                        }
                        listen_ports[n] = listen_port;
                        report.node_ports[n] =
                            s.peer_addr().map_err(|_| CommError::WorkerLost)?.port();
                        conns[n] = Some(s);
                    }
                    _ => return Err(CommError::WorkerLost),
                }
            }
            let peers = Frame::Peers { ports: listen_ports };
            let mut children: Vec<(usize, TcpStream)> = Vec::with_capacity(k);
            for node in 0..k {
                match conns[node].take() {
                    Some(mut s) => {
                        leader_sent += write_frame(
                            &mut s,
                            &Frame::Welcome { node: node as u32, parent_port: 0 },
                        )?;
                        leader_sent += write_frame(&mut s, &peers)?;
                        children.push((node, s));
                    }
                    None => return Err(CommError::WorkerLost),
                }
            }

            let mut total_bits = 0u64;
            for t in 1..=steps {
                let mut gather_s = 0.0f64;
                let mut broadcast_s = 0.0f64;
                let mut payload_bits = 0u64;
                let mut peak_link = 0.0f64;
                let mut report_bytes = 0u64;
                let mut round_mean: Vec<f64> = Vec::new();
                for (node, s) in children.iter_mut() {
                    let (frame, n) = read_frame(s)?;
                    report_bytes += n;
                    match frame {
                        Frame::ShardReport {
                            node: pn,
                            round,
                            payload_bits: bits,
                            comm_shard_s,
                            comm_slice_s,
                            max_link_bytes,
                            mean,
                        } if pn as usize == *node && round == t as u64 => {
                            gather_s = gather_s.max(comm_shard_s);
                            broadcast_s = broadcast_s.max(comm_slice_s);
                            payload_bits += bits;
                            peak_link = peak_link.max(max_link_bytes as f64);
                            if *node == 0 {
                                round_mean = mean;
                            }
                        }
                        _ => return Err(CommError::WorkerLost),
                    }
                }
                if round_mean.len() != d {
                    return Err(CommError::WorkerLost);
                }
                // the replica applies node 0's reported aggregate — every
                // mesh node assembled the identical mean, so the final
                // iterates still agree bit for bit
                (update)(&mut report.x, &round_mean, t);
                let comm_s = gather_s + broadcast_s;
                let (exposed, hidden) = plan.split(comm_s);
                report.comm_s += comm_s;
                report.comm_exposed_s += exposed;
                report.comm_hidden_s += hidden;
                report.payload_bits += payload_bits;
                report.peak_link_bytes = report.peak_link_bytes.max(peak_link);
                total_bits += payload_bits;
                report.rounds.push(WireRoundRecord {
                    round: t,
                    gather_s,
                    broadcast_s,
                    comm_s,
                    comm_exposed_s: exposed,
                    comm_hidden_s: hidden,
                    payload_bits,
                    frame_bytes: report_bytes,
                    peak_link_bytes: peak_link,
                });
                if t == steps {
                    report.last_mean.clone_from(&round_mean);
                }
                let rec = StepRecord {
                    t,
                    stats: StepStats {
                        bits: payload_bits,
                        quant_err_sq: 0.0,
                        dual_norm_sq: 0.0,
                    },
                    total_bits,
                    oracle_calls: (k * t) as u64,
                    gap: None,
                    comm_s,
                    comm_exposed_s: exposed,
                    comm_hidden_s: hidden,
                    peak_link_bytes: peak_link,
                };
                for sink in sinks.iter_mut() {
                    sink.on_step(&rec);
                }
            }
            Ok(())
        })();

        drop(listener);
        for h in handles {
            match h.join() {
                Ok(Ok(exit)) => {
                    report.frame_bytes += exit.sent;
                    if let Some(i) = worker_xs.iter().position(|w| w.is_none()) {
                        worker_xs[i] = Some(exit.x);
                    }
                }
                Ok(Err(e)) => worker_err = Some(e),
                Err(_) => worker_err = Some(CommError::WorkerLost),
            }
        }
        loop_result
    });

    run?;
    if let Some(e) = worker_err {
        return Err(e);
    }
    report.frame_bytes += leader_sent;
    for wx in worker_xs.iter().flatten() {
        debug_assert_eq!(wx, &report.x, "sharded wire replicas diverged");
    }
    Ok(report)
}

//! Length-prefixed frames over a byte stream: the wire form of the
//! handshake and of entropy-coded [`WirePacket`]s.
//!
//! Every frame is `magic (u32 LE) | body_len (u32 LE) | body`, where the
//! body starts with a one-byte kind tag. Integers are little-endian; a
//! packet blob ships its exact bit count plus the backing 64-bit words of
//! the [`crate::coding::BitBuf`], so the receiver reconstructs the payload
//! bit-for-bit (the parity tests hold decoded aggregates identical to the
//! in-process engines).
//!
//! Framing violations — bad magic, oversized bodies, truncated blobs,
//! mismatched word counts — are *peer* failures: they surface as
//! [`CommError::WorkerLost`] (the peer is unusable from here on), never a
//! panic and never an unbounded allocation.

use crate::coding::bitio::BitBuf;
use crate::comm::{CommError, WirePacket};
use std::io::{Read, Write};

/// Frame magic: `QODW` little-endian.
pub const MAGIC: u32 = 0x5744_4f51;

/// Wire protocol version carried by [`Frame::Hello`].
pub const PROTO_VERSION: u32 = 1;

/// Upper bound on a frame body (1 GiB) — a garbage length prefix must not
/// turn into an allocation.
pub const MAX_BODY_BYTES: u32 = 1 << 30;

const KIND_HELLO: u8 = 1;
const KIND_WELCOME: u8 = 2;
const KIND_PACKET: u8 = 3;
const KIND_BUNDLE: u8 = 4;
const KIND_SHARD: u8 = 5;
const KIND_SLICE: u8 = 6;
const KIND_REPORT: u8 = 7;
const KIND_PEERS: u8 = 8;

/// One frame of the wire protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// First frame on every connection: who is dialing in, which protocol
    /// revision it speaks, and (for a rack leader) the port its own
    /// member-facing listener was assigned by the OS — this is how the
    /// leader *collects* the port-0 bindings instead of configuring them.
    Hello { node: u32, listen_port: u16 },
    /// Leader's handshake reply: the port this node's upstream parent
    /// listens on (`0` = keep talking to the leader on this stream).
    Welcome { node: u32, parent_port: u16 },
    /// One node's entropy-coded dual for one round: node id, round tag and
    /// the packet blob (its exact bit count rides in the blob header).
    Packet { node: u32, round: u64, packet: WirePacket },
    /// A round-tagged set of node-tagged packets: a rack's gathered bundle
    /// on the way up, the full cluster set on the way down.
    Bundle { round: u64, packets: Vec<(u32, WirePacket)> },
    /// One node's coded shard for one owner of a sharded reduce-scatter
    /// round: the sender's sliced [`WirePacket`]
    /// ([`WirePacket::shard`](crate::comm::WirePacket::shard)) covering the
    /// receiving owner's layer range. Same blob layout as `Packet`; the
    /// distinct kind catches plan confusion at the framing layer.
    Shard { node: u32, round: u64, packet: WirePacket },
    /// An owner's reduced slice on the allgather leg: `lo` is the slice's
    /// first coordinate, `values` the bit-exact reduced aggregate over
    /// the owner's range (f64 bit patterns, LE).
    Slice { node: u32, round: u64, lo: u64, values: Vec<f64> },
    /// Control-plane round report from a sharded-exchange node to the
    /// leader: its own full packet's exact payload bits, its *measured*
    /// shard-exchange and slice-allgather seconds, the most bytes it
    /// shipped over any single mesh link, and (when non-empty) the full
    /// aggregate the leader's replica applies. Never counted as data-plane
    /// traffic.
    ShardReport {
        node: u32,
        round: u64,
        payload_bits: u64,
        comm_shard_s: f64,
        comm_slice_s: f64,
        max_link_bytes: u64,
        mean: Vec<f64>,
    },
    /// Leader → every node after the handshake of a sharded run: the full
    /// table of OS-assigned mesh listener ports, indexed by node.
    Peers { ports: Vec<u16> },
}

/// The peer broke the framing contract — treat it as lost.
fn protocol_err() -> CommError {
    CommError::WorkerLost
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Byte-stream cursor over a received frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CommError> {
        let end = self.pos.checked_add(n).ok_or_else(protocol_err)?;
        if end > self.buf.len() {
            return Err(protocol_err());
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CommError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, CommError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, CommError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, CommError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn usize64(&mut self) -> Result<usize, CommError> {
        usize::try_from(self.u64()?).map_err(|_| protocol_err())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Serialize an f64 slice: `count (u32) | bit patterns (u64 LE each)` —
/// exact, no decimal round-trip.
fn put_f64s(out: &mut Vec<u8>, values: &[f64]) {
    put_u32(out, values.len() as u32);
    for &v in values {
        put_u64(out, v.to_bits());
    }
}

fn get_f64s(c: &mut Cursor<'_>) -> Result<Vec<f64>, CommError> {
    let count = c.u32()? as usize;
    // 8 bytes per value: a garbage count can never out-allocate the body
    if count > c.remaining() / 8 {
        return Err(protocol_err());
    }
    let mut values = Vec::with_capacity(count);
    for _ in 0..count {
        values.push(f64::from_bits(c.u64()?));
    }
    Ok(values)
}

/// Serialize a packet blob: `dim (u64) | n_offsets (u32) | offsets (u64 ea)
/// | bits (u64) | words (u64 ea, exactly ceil(bits/64))`.
fn put_packet(out: &mut Vec<u8>, p: &WirePacket) {
    put_u64(out, p.dim() as u64);
    put_u32(out, p.layer_offsets().len() as u32);
    for &off in p.layer_offsets() {
        put_u64(out, off as u64);
    }
    put_u64(out, p.len_bits() as u64);
    for &w in p.payload().words() {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

fn get_packet(c: &mut Cursor<'_>) -> Result<WirePacket, CommError> {
    let dim = c.usize64()?;
    let n_offsets = c.u32()?;
    // a packet never has more layer segments than coordinates (+1 slack
    // for degenerate empty layers); cap before allocating
    if n_offsets as usize > dim.saturating_add(1) {
        return Err(protocol_err());
    }
    let mut offsets = Vec::with_capacity(n_offsets as usize);
    for _ in 0..n_offsets {
        offsets.push(c.usize64()?);
    }
    let bits = c.usize64()?;
    let n_words = bits.div_ceil(64);
    let mut words = Vec::with_capacity(n_words);
    for _ in 0..n_words {
        words.push(c.u64()?);
    }
    let payload = BitBuf::from_words(words, bits).ok_or_else(protocol_err)?;
    Ok(WirePacket::from_raw(payload, offsets, dim))
}

impl Frame {
    /// Serialize into a single contiguous byte vector (header + body), the
    /// unit one `write_all` ships.
    pub fn to_bytes(&self) -> Result<Vec<u8>, CommError> {
        let mut body = Vec::new();
        match self {
            Frame::Hello { node, listen_port } => {
                body.push(KIND_HELLO);
                put_u32(&mut body, PROTO_VERSION);
                put_u32(&mut body, *node);
                put_u16(&mut body, *listen_port);
            }
            Frame::Welcome { node, parent_port } => {
                body.push(KIND_WELCOME);
                put_u32(&mut body, *node);
                put_u16(&mut body, *parent_port);
            }
            Frame::Packet { node, round, packet } => {
                body.push(KIND_PACKET);
                put_u32(&mut body, *node);
                put_u64(&mut body, *round);
                put_packet(&mut body, packet);
            }
            Frame::Bundle { round, packets } => {
                body.push(KIND_BUNDLE);
                put_u64(&mut body, *round);
                put_u32(&mut body, packets.len() as u32);
                for (node, p) in packets {
                    put_u32(&mut body, *node);
                    put_packet(&mut body, p);
                }
            }
            Frame::Shard { node, round, packet } => {
                body.push(KIND_SHARD);
                put_u32(&mut body, *node);
                put_u64(&mut body, *round);
                put_packet(&mut body, packet);
            }
            Frame::Slice { node, round, lo, values } => {
                body.push(KIND_SLICE);
                put_u32(&mut body, *node);
                put_u64(&mut body, *round);
                put_u64(&mut body, *lo);
                put_f64s(&mut body, values);
            }
            Frame::ShardReport {
                node,
                round,
                payload_bits,
                comm_shard_s,
                comm_slice_s,
                max_link_bytes,
                mean,
            } => {
                body.push(KIND_REPORT);
                put_u32(&mut body, *node);
                put_u64(&mut body, *round);
                put_u64(&mut body, *payload_bits);
                put_u64(&mut body, comm_shard_s.to_bits());
                put_u64(&mut body, comm_slice_s.to_bits());
                put_u64(&mut body, *max_link_bytes);
                put_f64s(&mut body, mean);
            }
            Frame::Peers { ports } => {
                body.push(KIND_PEERS);
                put_u32(&mut body, ports.len() as u32);
                for &p in ports {
                    put_u16(&mut body, p);
                }
            }
        }
        seal(body)
    }

    /// Parse a frame body (everything after the 8-byte header). Rejects
    /// trailing bytes: a frame is exactly its fields.
    pub fn from_body(body: &[u8]) -> Result<Frame, CommError> {
        let mut c = Cursor { buf: body, pos: 0 };
        let frame = match c.u8()? {
            KIND_HELLO => {
                let version = c.u32()?;
                if version != PROTO_VERSION {
                    return Err(protocol_err());
                }
                Frame::Hello { node: c.u32()?, listen_port: c.u16()? }
            }
            KIND_WELCOME => Frame::Welcome { node: c.u32()?, parent_port: c.u16()? },
            KIND_PACKET => {
                let node = c.u32()?;
                let round = c.u64()?;
                Frame::Packet { node, round, packet: get_packet(&mut c)? }
            }
            KIND_BUNDLE => {
                let round = c.u64()?;
                let count = c.u32()?;
                // sanity cap: a bundle carries at most one packet per node
                // of a plausibly-sized cluster
                if count > 1 << 16 {
                    return Err(protocol_err());
                }
                let mut packets = Vec::with_capacity(count as usize);
                for _ in 0..count {
                    let node = c.u32()?;
                    packets.push((node, get_packet(&mut c)?));
                }
                Frame::Bundle { round, packets }
            }
            KIND_SHARD => {
                let node = c.u32()?;
                let round = c.u64()?;
                Frame::Shard { node, round, packet: get_packet(&mut c)? }
            }
            KIND_SLICE => {
                let node = c.u32()?;
                let round = c.u64()?;
                let lo = c.u64()?;
                Frame::Slice { node, round, lo, values: get_f64s(&mut c)? }
            }
            KIND_REPORT => Frame::ShardReport {
                node: c.u32()?,
                round: c.u64()?,
                payload_bits: c.u64()?,
                comm_shard_s: f64::from_bits(c.u64()?),
                comm_slice_s: f64::from_bits(c.u64()?),
                max_link_bytes: c.u64()?,
                mean: get_f64s(&mut c)?,
            },
            KIND_PEERS => {
                let count = c.u32()? as usize;
                // ports are 2 bytes each; the count can never exceed what
                // the body actually holds
                if count > c.remaining() / 2 {
                    return Err(protocol_err());
                }
                let mut ports = Vec::with_capacity(count);
                for _ in 0..count {
                    ports.push(c.u16()?);
                }
                Frame::Peers { ports }
            }
            _ => return Err(protocol_err()),
        };
        if !c.done() {
            return Err(protocol_err());
        }
        Ok(frame)
    }
}

fn seal(body: Vec<u8>) -> Result<Vec<u8>, CommError> {
    let len = u32::try_from(body.len()).map_err(|_| protocol_err())?;
    if len > MAX_BODY_BYTES {
        return Err(protocol_err());
    }
    let mut out = Vec::with_capacity(8 + body.len());
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, len);
    out.extend_from_slice(&body);
    Ok(out)
}

/// Serialize a [`Frame::Packet`] without taking ownership of the packet —
/// the per-round hot path (workers re-encode into the same scratch packet
/// every round and must not clone it to ship it).
pub fn packet_frame_bytes(
    node: u32,
    round: u64,
    p: &WirePacket,
) -> Result<Vec<u8>, CommError> {
    let mut body = Vec::new();
    body.push(KIND_PACKET);
    put_u32(&mut body, node);
    put_u64(&mut body, round);
    put_packet(&mut body, p);
    seal(body)
}

/// Serialize a [`Frame::Bundle`] from borrowed packets (rack gather on the
/// way up, the full cluster set on the way down).
pub fn bundle_frame_bytes(
    round: u64,
    packets: &[(u32, &WirePacket)],
) -> Result<Vec<u8>, CommError> {
    let mut body = Vec::new();
    body.push(KIND_BUNDLE);
    put_u64(&mut body, round);
    put_u32(&mut body, packets.len() as u32);
    for (node, p) in packets {
        put_u32(&mut body, *node);
        put_packet(&mut body, p);
    }
    seal(body)
}

/// Serialize a [`Frame::Shard`] from a borrowed sliced packet — the
/// per-round hot path of the sharded mesh exchange.
pub fn shard_frame_bytes(
    node: u32,
    round: u64,
    p: &WirePacket,
) -> Result<Vec<u8>, CommError> {
    let mut body = Vec::new();
    body.push(KIND_SHARD);
    put_u32(&mut body, node);
    put_u64(&mut body, round);
    put_packet(&mut body, p);
    seal(body)
}

/// Serialize a [`Frame::Slice`] from a borrowed reduced slice.
pub fn slice_frame_bytes(
    node: u32,
    round: u64,
    lo: u64,
    values: &[f64],
) -> Result<Vec<u8>, CommError> {
    let mut body = Vec::new();
    body.push(KIND_SLICE);
    put_u32(&mut body, node);
    put_u64(&mut body, round);
    put_u64(&mut body, lo);
    put_f64s(&mut body, values);
    seal(body)
}

/// Ship pre-serialized frame bytes; returns the byte count on success.
pub fn write_all_bytes(w: &mut impl Write, bytes: &[u8]) -> Result<u64, CommError> {
    w.write_all(bytes).map_err(|_| CommError::WorkerLost)?;
    w.flush().map_err(|_| CommError::WorkerLost)?;
    Ok(bytes.len() as u64)
}

/// Write one frame to `w`; returns the bytes shipped. Any I/O failure
/// (including a write timeout) means the peer is gone: [`CommError::WorkerLost`].
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<u64, CommError> {
    let bytes = frame.to_bytes()?;
    w.write_all(&bytes).map_err(|_| CommError::WorkerLost)?;
    w.flush().map_err(|_| CommError::WorkerLost)?;
    Ok(bytes.len() as u64)
}

/// Read one frame from `r`; returns the frame and the bytes consumed. EOF,
/// a read timeout, bad magic or an oversized body all surface as
/// [`CommError::WorkerLost`] — a blocking read can never hang past the
/// stream's configured timeout, and a dead peer never deadlocks a round.
pub fn read_frame(r: &mut impl Read) -> Result<(Frame, u64), CommError> {
    let (frame, raw) = read_frame_bytes(r)?;
    Ok((frame, raw.len() as u64))
}

/// [`read_frame`], also returning the raw wire bytes (header + body) so a
/// relay — a rack leader fanning the leader's full-set bundle down to its
/// members — can forward the frame verbatim without re-serializing it.
pub fn read_frame_bytes(r: &mut impl Read) -> Result<(Frame, Vec<u8>), CommError> {
    let mut header = [0u8; 8];
    r.read_exact(&mut header).map_err(|_| CommError::WorkerLost)?;
    let magic = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
    if magic != MAGIC || len > MAX_BODY_BYTES {
        return Err(protocol_err());
    }
    let mut raw = vec![0u8; 8 + len as usize];
    raw[..8].copy_from_slice(&header);
    r.read_exact(&mut raw[8..]).map_err(|_| CommError::WorkerLost)?;
    let frame = Frame::from_body(&raw[8..])?;
    Ok((frame, raw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Compressor, IdentityCompressor};

    fn sample_packet() -> WirePacket {
        let mut codec = IdentityCompressor::new();
        codec.encode(&[1.5, -2.25, 0.0, 42.0]).expect("encode")
    }

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = f.to_bytes().expect("to_bytes");
        let mut r = &bytes[..];
        let (got, n) = read_frame(&mut r).expect("read_frame");
        assert_eq!(n, bytes.len() as u64);
        got
    }

    #[test]
    fn handshake_frames_roundtrip() {
        for f in [
            Frame::Hello { node: 7, listen_port: 45231 },
            Frame::Welcome { node: 7, parent_port: 0 },
        ] {
            assert_eq!(roundtrip(&f), f);
        }
    }

    #[test]
    fn packet_frame_roundtrips_bit_exact() {
        let p = sample_packet();
        let f = Frame::Packet { node: 3, round: 99, packet: p.clone() };
        match roundtrip(&f) {
            Frame::Packet { node, round, packet } => {
                assert_eq!((node, round), (3, 99));
                assert_eq!(packet.len_bits(), p.len_bits());
                assert_eq!(packet.dim(), p.dim());
                assert_eq!(packet.layer_offsets(), p.layer_offsets());
                assert_eq!(packet.payload().words(), p.payload().words());
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn bundle_frame_roundtrips() {
        let f = Frame::Bundle {
            round: 5,
            packets: vec![(0, sample_packet()), (2, sample_packet())],
        };
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn sharded_mesh_frames_roundtrip() {
        let p = sample_packet();
        let shard = Frame::Shard { node: 5, round: 12, packet: p.clone() };
        assert_eq!(roundtrip(&shard), shard);
        // borrowed serializer matches the owned one byte for byte
        assert_eq!(
            shard_frame_bytes(5, 12, &p).unwrap(),
            shard.to_bytes().unwrap()
        );
        let slice = Frame::Slice {
            node: 2,
            round: 12,
            lo: 640,
            values: vec![1.5, -0.25, f64::MIN_POSITIVE],
        };
        assert_eq!(roundtrip(&slice), slice);
        assert_eq!(
            slice_frame_bytes(2, 12, 640, &[1.5, -0.25, f64::MIN_POSITIVE]).unwrap(),
            slice.to_bytes().unwrap()
        );
        let report = Frame::ShardReport {
            node: 0,
            round: 3,
            payload_bits: 8191,
            comm_shard_s: 0.0125,
            comm_slice_s: 0.0075,
            max_link_bytes: 4096,
            mean: vec![0.5; 4],
        };
        assert_eq!(roundtrip(&report), report);
        let peers = Frame::Peers { ports: vec![50123, 50124, 0, 65535] };
        assert_eq!(roundtrip(&peers), peers);
    }

    #[test]
    fn mesh_frame_counts_cannot_out_allocate_the_body() {
        // a Slice whose value count claims far more f64s than the body
        // holds must be rejected before allocating
        let mut bytes =
            Frame::Slice { node: 1, round: 1, lo: 0, values: vec![1.0] }.to_bytes().unwrap();
        // value-count u32 sits right after kind(1)+node(4)+round(8)+lo(8)
        let at = 8 + 1 + 4 + 8 + 8;
        bytes[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(read_frame(&mut &bytes[..]).unwrap_err(), CommError::WorkerLost);
        // same for the Peers port table
        let mut bytes = Frame::Peers { ports: vec![1, 2] }.to_bytes().unwrap();
        let at = 8 + 1;
        bytes[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(read_frame(&mut &bytes[..]).unwrap_err(), CommError::WorkerLost);
    }

    #[test]
    fn garbage_is_worker_lost_not_panic() {
        // bad magic
        let mut bytes = Frame::Hello { node: 0, listen_port: 0 }.to_bytes().unwrap();
        bytes[0] ^= 0xFF;
        assert_eq!(read_frame(&mut &bytes[..]).unwrap_err(), CommError::WorkerLost);
        // truncated body
        let bytes = Frame::Packet { node: 1, round: 1, packet: sample_packet() }
            .to_bytes()
            .unwrap();
        let cut = &bytes[..bytes.len() - 3];
        assert_eq!(read_frame(&mut &cut[..]).unwrap_err(), CommError::WorkerLost);
        // oversized length prefix must not allocate
        let mut huge = Vec::new();
        huge.extend_from_slice(&MAGIC.to_le_bytes());
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(read_frame(&mut &huge[..]).unwrap_err(), CommError::WorkerLost);
        // trailing bytes after a valid body
        let mut padded = Frame::Welcome { node: 1, parent_port: 2 }.to_bytes().unwrap();
        padded.push(0);
        let len = (padded.len() - 8) as u32;
        padded[4..8].copy_from_slice(&len.to_le_bytes());
        assert_eq!(read_frame(&mut &padded[..]).unwrap_err(), CommError::WorkerLost);
        // unknown kind
        let mut unk = Vec::new();
        unk.extend_from_slice(&MAGIC.to_le_bytes());
        unk.extend_from_slice(&1u32.to_le_bytes());
        unk.push(0xEE);
        assert_eq!(read_frame(&mut &unk[..]).unwrap_err(), CommError::WorkerLost);
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut bytes = Frame::Hello { node: 0, listen_port: 1 }.to_bytes().unwrap();
        // bump the version field (first body u32 after the kind byte)
        bytes[9] ^= 0x01;
        assert_eq!(read_frame(&mut &bytes[..]).unwrap_err(), CommError::WorkerLost);
    }
}

//! Measured-wire TCP runtime: the repo's third coordinator engine, where
//! `comm_s` is a **measurement**, not a model.
//!
//! Everything else in this repo that reports communication seconds charges
//! an analytic clock ([`crate::net::NetworkModel`] routed through a
//! [`crate::coordinator::topology::Transport`]). This subsystem ships the
//! *actual* entropy-coded [`crate::comm::WirePacket`] bytes over real
//! localhost TCP sockets — every node a real OS thread — and wraps each
//! socket phase in a monotonic [`std::time::Instant`]. Nothing under
//! `wire/` calls the charge model; the invariant is pinned by the module
//! layout (no `net::` charging import exists here) and audited by the
//! `qoda audit` wire-module rules, which cover this directory.
//!
//! # Frame format
//!
//! Every frame on every stream is
//!
//! ```text
//! magic (u32 LE = "QODW") | body_len (u32 LE) | body
//! ```
//!
//! where the body starts with a one-byte kind tag: `Hello` / `Welcome`
//! (handshake), `Packet` (one node's round-tagged entropy-coded dual) and
//! `Bundle` (a round-tagged set of node-tagged packets — a rack's gather on
//! the way up, the full cluster set on the way down). A packet blob carries
//! its exact bit count plus the backing 64-bit words of the
//! [`crate::coding::BitBuf`], so the receiver reconstructs the payload
//! bit-for-bit and decoded aggregates stay identical to the in-process
//! engines (the `wire_e2e` suite pins this across protocols and seeds).
//! See [`frame`] for the full grammar and its hardening (body-size cap,
//! no-alloc rejection of garbage length prefixes, trailing-byte rejection).
//!
//! # Handshake and deterministic socket setup
//!
//! No fixed ports anywhere: every listener binds port 0 and the
//! OS-assigned ports travel *through the protocol*. The leader binds an
//! ephemeral listener; each worker dials it (bounded-backoff retries, then
//! [`crate::comm::CommError::WorkerLost`]) and sends `Hello { node,
//! listen_port }` — where `listen_port` is the worker's own member-facing
//! listener if the topology makes it a rack leader, else 0. The leader
//! collects all K Hellos, then answers each with `Welcome { node,
//! parent_port }`: 0 means "keep talking to me on this stream", a rack
//! member instead receives its rack leader's collected port, dials it, and
//! drops the leader stream. Handshake complete; round frames flow only on
//! the data plane.
//!
//! # Measured-clock semantics
//!
//! The leader's round loop times two phases with a monotonic clock: the
//! **gather** (blocked in socket reads until all K round-tagged packets
//! arrived) and the **broadcast** (writing the full coded packet set back
//! down). Their sum is the round's `comm_s`; the exposed-vs-hidden split
//! reuses [`crate::coordinator::topology::ExchangePlan::split`] — the same
//! arithmetic `PhaseTimeline` applies to modeled charges, fed measured
//! seconds. Under an overlapped plan the engine overlaps *actual* latency:
//! workers ship round t+1 before consuming round t, and the leader drains
//! the t+1 uplink before writing the t downlink (read-before-write keeps
//! finite kernel socket buffers from wedging the pipeline). Dead peers
//! never hang a round: every stream carries read/write timeouts and every
//! failure surfaces as `CommError::WorkerLost`.
//!
//! The exchange is an allgather over a star (flat) or a two-level tree
//! (hierarchical, via [`crate::coordinator::topology::rack_spans`]): the
//! downlink carries the *coded packet set*, not fp32 iterates, so the
//! coded-vs-uncompressed wire ratio survives on both directions, and every
//! node decodes all K packets through the one shared
//! [`crate::coordinator::core::decode_aggregate_into`] rule — aggregates
//! are bit-identical to `ClusterSim` and the threaded engine by
//! construction, not by tuning.

pub mod cluster;
pub mod frame;
pub mod socket;

pub use cluster::{
    run_wire, run_wire_observed, WireCodecSpec, WireOptions, WireReport,
    WireRoundRecord, Workload,
};
pub use frame::Frame;
pub use socket::SocketConfig;

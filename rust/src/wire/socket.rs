//! Deterministic socket setup for the measured-wire runtime.
//!
//! Every listener binds port 0 and lets the OS pick — there are no fixed
//! ports anywhere, so concurrent CI runs can never collide. The assigned
//! ports travel through the [`super::frame::Frame::Hello`] handshake: a
//! rack leader reports its member-facing listener port to the cluster
//! leader, which relays it to the rack's members in their `Welcome`.
//!
//! Connecting retries with bounded exponential backoff (a member may dial
//! its rack leader before that listener exists); once the budget is spent
//! the peer counts as lost — [`CommError::WorkerLost`], never a hang.

use crate::comm::CommError;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

/// Socket behavior knobs shared by every node of a wire run.
#[derive(Clone, Copy, Debug)]
pub struct SocketConfig {
    /// Per-read deadline on every stream: a dead peer surfaces as
    /// [`CommError::WorkerLost`] within this bound instead of hanging the
    /// round.
    pub read_timeout: Duration,
    /// Per-write deadline (a peer that stopped draining counts as lost).
    pub write_timeout: Duration,
    /// How many times to retry a refused connection before giving up.
    pub connect_retries: u32,
    /// Initial retry backoff; doubles per attempt, capped at 100 ms.
    pub connect_backoff: Duration,
}

impl Default for SocketConfig {
    fn default() -> Self {
        SocketConfig {
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            connect_retries: 40,
            connect_backoff: Duration::from_millis(2),
        }
    }
}

impl SocketConfig {
    /// Apply the read/write deadlines to a connected stream and disable
    /// Nagle (the runtime ships whole frames; latency matters, batching
    /// does not).
    pub fn configure(&self, stream: &TcpStream) -> Result<(), CommError> {
        stream
            .set_read_timeout(Some(self.read_timeout))
            .map_err(|_| CommError::WorkerLost)?;
        stream
            .set_write_timeout(Some(self.write_timeout))
            .map_err(|_| CommError::WorkerLost)?;
        stream.set_nodelay(true).map_err(|_| CommError::WorkerLost)?;
        Ok(())
    }
}

/// Bind a fresh localhost listener on an OS-assigned port. Returns the
/// listener and the port the OS picked (what the handshake reports).
pub fn bind_ephemeral() -> Result<(TcpListener, u16), CommError> {
    let listener =
        TcpListener::bind(("127.0.0.1", 0)).map_err(|_| CommError::WorkerLost)?;
    let port = listener
        .local_addr()
        .map_err(|_| CommError::WorkerLost)?
        .port();
    Ok((listener, port))
}

/// Dial `addr` with bounded exponential backoff; configure deadlines on
/// success. Exhausting the retry budget is [`CommError::WorkerLost`].
pub fn connect_with_backoff(
    addr: SocketAddr,
    cfg: &SocketConfig,
) -> Result<TcpStream, CommError> {
    let mut backoff = cfg.connect_backoff;
    let cap = Duration::from_millis(100);
    for attempt in 0..=cfg.connect_retries {
        match TcpStream::connect_timeout(&addr, cfg.read_timeout) {
            Ok(stream) => {
                cfg.configure(&stream)?;
                return Ok(stream);
            }
            Err(_) if attempt < cfg.connect_retries => {
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(cap);
            }
            Err(_) => break,
        }
    }
    Err(CommError::WorkerLost)
}

/// Accept one inbound connection and configure its deadlines. The listener
/// must have a read timeout story of its own: accept blocks, so the caller
/// bounds total setup time via the retry/backoff budget on the dialing
/// side plus this listener's scope.
pub fn accept_configured(
    listener: &TcpListener,
    cfg: &SocketConfig,
) -> Result<TcpStream, CommError> {
    let (stream, _) = listener.accept().map_err(|_| CommError::WorkerLost)?;
    cfg.configure(&stream)?;
    Ok(stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ephemeral_bind_yields_distinct_live_ports() {
        let (a, pa) = bind_ephemeral().unwrap();
        let (b, pb) = bind_ephemeral().unwrap();
        assert_ne!(pa, 0);
        assert_ne!(pb, 0);
        assert_ne!(pa, pb);
        drop((a, b));
    }

    #[test]
    fn connect_backoff_eventually_gives_up() {
        // bind-then-drop leaves a port that refuses connections
        let (listener, port) = bind_ephemeral().unwrap();
        drop(listener);
        let cfg = SocketConfig {
            connect_retries: 3,
            connect_backoff: Duration::from_millis(1),
            ..SocketConfig::default()
        };
        let addr: SocketAddr = ([127, 0, 0, 1], port).into();
        assert_eq!(connect_with_backoff(addr, &cfg).unwrap_err(), CommError::WorkerLost);
    }

    #[test]
    fn connect_succeeds_against_live_listener() {
        let (listener, port) = bind_ephemeral().unwrap();
        let cfg = SocketConfig::default();
        let addr: SocketAddr = ([127, 0, 0, 1], port).into();
        let dial = std::thread::spawn(move || connect_with_backoff(addr, &cfg));
        let accepted = accept_configured(&listener, &SocketConfig::default()).unwrap();
        let dialed = dial.join().expect("dial thread").unwrap();
        assert_eq!(
            accepted.local_addr().unwrap().port(),
            dialed.peer_addr().unwrap().port()
        );
    }
}

//! The auditor audited: fixture-based self-tests for every `qoda audit`
//! rule (detection, pragma suppression, stale-pragma rejection) plus the
//! meta-test that the live tree is clean — the test CI's blocking `audit`
//! job re-runs through the CLI.
//!
//! The fixture files under `tests/audit_fixtures/src/` are *data*, not
//! code: cargo only compiles top-level `tests/*.rs`, so the deliberately
//! broken sources in the subdirectory never build.

use qoda::analysis::{run_audit, rules, AuditReport};
use std::path::Path;

fn fixture_root() -> &'static Path {
    Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/audit_fixtures/src"
    ))
}

fn live_root() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"))
}

fn fixture_report() -> AuditReport {
    run_audit(fixture_root()).expect("fixture tree walks")
}

fn violations(r: &AuditReport) -> Vec<(&'static str, String, u32)> {
    r.violations()
        .map(|f| (f.rule, f.file.clone(), f.line))
        .collect()
}

#[test]
fn every_rule_detects_its_fixture() {
    let r = fixture_report();
    let v = violations(&r);
    let expect: &[(&str, &str, u32)] = &[
        // hash-container: import + type + construction
        (rules::RULE_HASH, "comm/determinism_bad.rs", 4),
        (rules::RULE_HASH, "comm/determinism_bad.rs", 6),
        (rules::RULE_HASH, "comm/determinism_bad.rs", 7),
        // panic-path: unwrap, expect, panic!, unreachable!
        (rules::RULE_PANIC, "coding/panic_bad.rs", 5),
        (rules::RULE_PANIC, "coding/panic_bad.rs", 6),
        (rules::RULE_PANIC, "coding/panic_bad.rs", 8),
        (rules::RULE_PANIC, "coding/panic_bad.rs", 12),
        // rng-clone: the unjustified clone only
        (rules::RULE_RNG, "coordinator/rng_bad.rs", 14),
        // lossy-cast: f32 + u16 narrowing, not the u8->u32 widening
        (rules::RULE_CAST, "quant/cast_bad.rs", 5),
        (rules::RULE_CAST, "quant/cast_bad.rs", 9),
    ];
    for (rule, file, line) in expect {
        assert!(
            v.iter().any(|(r2, f2, l2)| r2 == rule && f2 == file && l2 == line),
            "missing expected finding {rule} {file}:{line}; got {v:?}"
        );
    }
    assert_eq!(v.len(), expect.len(), "unexpected extra findings: {v:?}");
}

#[test]
fn negative_fixtures_stay_silent() {
    let r = fixture_report();
    for silent in [
        "comm/determinism_ok.rs",   // BTreeMap + hash names in strings/comments/tests
        "quant/quantizer.rs",       // lossy-cast owner module
        "util/outside.rs",          // outside the wire-affecting scope
    ] {
        assert!(
            !r.violations().any(|f| f.file == silent),
            "{silent} should produce no violations"
        );
    }
}

#[test]
fn pragmas_suppress_and_record_reasons() {
    let r = fixture_report();
    let allowed: Vec<_> = r.allowed().collect();
    assert_eq!(allowed.len(), 3, "{allowed:?}");
    // trailing form
    assert!(allowed.iter().any(|f| {
        f.file == "coding/panic_allowed.rs"
            && f.line == 5
            && f.reason.as_deref() == Some("caller guarantees non-empty")
    }));
    // standalone form covers the next code line
    assert!(allowed.iter().any(|f| {
        f.file == "coding/panic_allowed.rs"
            && f.line == 10
            && f.reason.as_deref() == Some("constructor always sets this field")
    }));
    // justified rng splice site
    assert!(allowed
        .iter()
        .any(|f| f.file == "coordinator/rng_bad.rs" && f.rule == rules::RULE_RNG));
    // suppressed findings are not violations
    assert!(!r.violations().any(|f| f.file == "coding/panic_allowed.rs"));
}

#[test]
fn bad_pragmas_are_rejected() {
    let r = fixture_report();
    let issues: Vec<_> = r
        .pragma_issues
        .iter()
        .filter(|p| p.file == "coding/stale_pragma.rs")
        .collect();
    assert_eq!(issues.len(), 3, "{issues:?}");
    assert!(issues
        .iter()
        .any(|p| p.line == 4 && p.problem.starts_with("stale")));
    assert!(issues
        .iter()
        .any(|p| p.line == 9 && p.problem.contains("unknown rule")));
    assert!(issues
        .iter()
        .any(|p| p.line == 12 && p.problem.contains("missing justification")));
    // any pragma issue fails the audit even with zero violations elsewhere
    assert!(!r.clean());
}

#[test]
fn live_tree_is_clean() {
    let r = run_audit(live_root()).expect("live tree walks");
    let mut complaints = String::new();
    for f in r.violations() {
        complaints.push_str(&format!("  {}:{} [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    for p in &r.pragma_issues {
        complaints.push_str(&format!(
            "  {}:{} pragma audit:allow({}) {}\n",
            p.file, p.line, p.rule, p.problem
        ));
    }
    assert!(
        r.clean(),
        "`qoda audit` must pass on the live tree; fix or justify:\n{complaints}"
    );
    // the justified exceptions stay few and deliberate — if this number
    // grows, each new allow needs the same scrutiny as a parity change
    assert!(
        r.allowed().count() <= 16,
        "allowed findings ballooned: {}",
        r.allowed().count()
    );
}

#[test]
fn json_report_is_well_formed_and_stable() {
    let r = fixture_report();
    let j = r.to_json();
    assert!(j.contains("\"schema\": \"qoda-audit/1\""));
    assert!(j.contains("\"clean\": false"));
    assert_eq!(j.matches('{').count(), j.matches('}').count());
    // deterministic across runs (sorted file walk)
    assert_eq!(j, fixture_report().to_json());
}

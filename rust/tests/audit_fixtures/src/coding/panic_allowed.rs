//! Fixture: pragma suppression — trailing and standalone forms.
//! NOT compiled — data for `tests/audit.rs` only.

pub fn trailing(v: &[u32]) -> u32 {
    *v.last().unwrap() // audit:allow(panic-path) — caller guarantees non-empty
}

pub fn standalone(v: Option<u32>) -> u32 {
    // audit:allow(panic-path) — constructor always sets this field
    v.unwrap()
}

//! Fixture: panic-path violations on a decode path.
//! NOT compiled — data for `tests/audit.rs` only.

pub fn decode_header(bytes: &[u8]) -> u32 {
    let first = bytes.first().unwrap();
    let second = bytes.get(1).expect("second byte");
    if *first == 0 {
        panic!("zero header");
    }
    match second {
        0..=254 => (*first as u32) << 8 | *second as u32,
        _ => unreachable!(),
    }
}

pub fn not_a_panic(v: Option<u32>) -> u32 {
    // unwrap_or_else is its own identifier, not a `.unwrap()` call
    v.unwrap_or_else(|| 0)
}

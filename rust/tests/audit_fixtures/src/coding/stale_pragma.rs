//! Fixture: pragma rejection — stale, unknown rule, missing reason.
//! NOT compiled — data for `tests/audit.rs` only.

// audit:allow(panic-path) — the unwrap this justified was refactored away
pub fn now_clean(v: Option<u32>) -> u32 {
    v.unwrap_or(0)
}

// audit:allow(no-such-rule) — rule name does not exist
pub fn also_clean() {}

// audit:allow(hash-container)
pub fn missing_reason() {}

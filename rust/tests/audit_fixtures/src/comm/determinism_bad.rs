//! Fixture: hash-container violations in a wire-scoped module.
//! NOT compiled — data for `tests/audit.rs` only.

use std::collections::HashMap;

pub fn build_codebook_badly(symbols: &[usize]) -> HashMap<usize, u64> {
    let mut m = HashMap::new();
    for (code, &s) in symbols.iter().enumerate() {
        m.insert(s, code as u64);
    }
    m
}

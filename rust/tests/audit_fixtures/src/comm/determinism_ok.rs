//! Fixture: the deterministic counterpart — BTreeMap in live code, hash
//! containers only where the rules must NOT look: strings, comments, tests.
//! NOT compiled — data for `tests/audit.rs` only.

use std::collections::BTreeMap;

/// A comment mentioning HashMap is not a finding.
pub fn build_codebook(symbols: &[usize]) -> BTreeMap<usize, u64> {
    let note = "HashMap inside a string literal is not a finding";
    let _ = note;
    symbols
        .iter()
        .enumerate()
        .map(|(code, &s)| (s, code as u64))
        .collect()
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    #[test]
    fn tests_may_use_hash_containers() {
        let mut seen = HashSet::new();
        seen.insert(1);
        assert!(seen.contains(&1));
        // tests may also unwrap freely
        let v: Option<u32> = Some(2);
        assert_eq!(v.unwrap(), 2);
    }
}

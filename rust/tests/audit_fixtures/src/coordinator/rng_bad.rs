//! Fixture: rng-clone — an unjustified clone is a finding, a justified
//! parallel-splice clone is allowed.
//! NOT compiled — data for `tests/audit.rs` only.

pub struct Rng(u64);

impl Rng {
    pub fn clone(&self) -> Rng {
        Rng(self.0)
    }
}

pub fn desync(rng: &Rng) -> Rng {
    rng.clone()
}

pub fn splice(worker_rng: &Rng) -> Rng {
    // audit:allow(rng-clone) — splice site: leader stream advanced past this chunk's draws
    worker_rng.clone()
}

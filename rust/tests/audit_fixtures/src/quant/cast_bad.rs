//! Fixture: lossy-cast violations outside the owner modules.
//! NOT compiled — data for `tests/audit.rs` only.

pub fn shrink(x: f64) -> f32 {
    x as f32
}

pub fn index(n: usize) -> u16 {
    n as u16
}

pub fn widen_is_fine(l: u8) -> u32 {
    l as u32
}

//! Fixture: quant/quantizer.rs is a lossy-cast OWNER — truncation here is
//! the contract (mapping f64 activations onto the fp32 level ladder).
//! NOT compiled — data for `tests/audit.rs` only.

pub fn to_wire(x: f64) -> f32 {
    x as f32
}

pub fn symbol(sym: usize) -> u8 {
    sym as u8
}

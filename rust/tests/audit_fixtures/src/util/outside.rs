//! Fixture: util/ is outside the wire-affecting scope — none of the rules
//! apply here, whatever the code does.
//! NOT compiled — data for `tests/audit.rs` only.

use std::collections::HashMap;

pub fn scratch(v: Option<u32>) -> u32 {
    let mut m: HashMap<u32, f32> = HashMap::new();
    m.insert(1, 2.0f64 as f32);
    v.unwrap()
}

//! Property/fuzz tests for the comm codec: `WirePacket` encode → decode
//! roundtrips over randomized layer shapes, level widths and protocols, and
//! adversarial wire bytes (truncations, bit flips) that must surface
//! `CommError`/`DecodeError` — never a panic. This is the standing
//! regression guard for the old `panic!("corrupt huffman stream")`: decode
//! operates on untrusted wire data and is fallible end to end.
//!
//! Uses the in-tree seeded property harness (`qoda::util::prop`) — the
//! environment is offline, no proptest; every failing case reports its
//! replayable seed.

use qoda::coding::bitio::{BitBuf, BitWriter};
use qoda::coding::protocol::ProtocolKind;
use qoda::coding::DecodeError;
use qoda::comm::{
    Adaptation, CommError, Compressor, IdentityCompressor, QuantCompressor, WirePacket,
};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::QuantConfig;
use qoda::util::prop::{for_cases, Gen};

/// Random heterogeneous layer map: 1–4 layers, each its own type, sizes
/// 8–300 coordinates.
fn random_map(g: &mut Gen) -> LayerMap {
    let n_layers = g.usize_in(1, 4);
    let spec: Vec<(String, usize, String)> = (0..n_layers)
        .map(|i| (format!("l{i}"), g.usize_in(8, 300), format!("t{i}")))
        .collect();
    let spec_ref: Vec<(&str, usize, &str)> =
        spec.iter().map(|(n, len, ty)| (n.as_str(), *len, ty.as_str())).collect();
    LayerMap::from_spec(&spec_ref)
}

fn random_codec(g: &mut Gen, map: &LayerMap) -> QuantCompressor {
    let bits = g.usize_in(2, 7) as u32;
    let protocol = if g.f64_in(0.0, 1.0) < 0.5 {
        ProtocolKind::Main
    } else {
        ProtocolKind::Alternating
    };
    let cfg = QuantConfig::uniform_bits(map.num_types(), bits, 2.0);
    let seed = g.rng.next_u64();
    QuantCompressor::new(map.clone(), cfg, protocol, Adaptation::Fixed, seed)
}

/// Copy `payload`, optionally truncating to `keep_bits` and XOR-flipping
/// the bit at `flip` (if given). Pure bit plumbing via the public reader.
fn mutate_payload(
    payload: &BitBuf,
    keep_bits: usize,
    flip: Option<usize>,
) -> BitBuf {
    let mut r = payload.reader();
    let mut w = BitWriter::new();
    let mut pos = 0usize;
    while pos < keep_bits {
        let take = (keep_bits - pos).min(64) as u32;
        let mut word = r.read_bits(take);
        if let Some(f) = flip {
            if f >= pos && f < pos + take as usize {
                word ^= 1u64 << (f - pos);
            }
        }
        w.write_bits(word, take);
        pos += take as usize;
    }
    w.finish()
}

#[test]
fn quantized_roundtrip_over_random_shapes_and_levels() {
    for_cases(60, 0xC0DEC, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let scale = g.f64_in(0.05, 8.0);
        let v = g.vec_f64(map.dim, scale);
        let packet = codec.encode(&v);
        // the packet frames the stream: one offset per layer, inside the
        // payload, starting at 0, strictly increasing
        assert_eq!(packet.dim(), map.dim);
        assert_eq!(packet.layer_offsets().len(), map.layers.len());
        assert_eq!(packet.layer_offsets()[0], 0);
        for w in packet.layer_offsets().windows(2) {
            assert!(w[0] < w[1], "offsets must increase: {:?}", packet.layer_offsets());
        }
        assert!(packet.len_bits() > 0);
        // decode reconstructs the exact dimensionality, all finite
        let out = codec.decode(&packet).expect("roundtrip decode");
        assert_eq!(out.len(), map.dim);
        assert!(out.iter().all(|x| x.is_finite()));
        // unbiased-ish reconstruction: positively correlated with the input
        let dot: f64 = v.iter().zip(&out).map(|(a, b)| a * b).sum();
        let norm: f64 = v.iter().map(|a| a * a).sum();
        assert!(dot > -0.25 * norm, "reconstruction anti-correlated: {dot} vs {norm}");
    });
}

#[test]
fn identity_roundtrip_is_exact_f32() {
    for_cases(30, 0x1DE27, |g| {
        let n = g.usize_in(1, 400);
        let v = g.vec_f64(n, 3.0);
        let mut c = IdentityCompressor;
        let packet = c.encode(&v);
        assert_eq!(packet.len_bits(), 32 * n);
        let out = c.decode(&packet).expect("identity decode");
        let want: Vec<f64> = v.iter().map(|&x| x as f32 as f64).collect();
        assert_eq!(out, want);
    });
}

#[test]
fn truncated_streams_error_and_never_panic() {
    for_cases(60, 0x7213C, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let v = g.vec_f64(map.dim, 1.0);
        let packet = codec.encode(&v);
        let n = packet.len_bits();
        // any strict prefix must fail during decode: the full stream is
        // consumed exactly on success, so fewer bits always run dry
        let cut = g.usize_in(0, n - 1);
        let short = WirePacket::from_raw(
            mutate_payload(packet.payload(), cut, None),
            packet.layer_offsets().to_vec(),
            map.dim,
        );
        match codec.decode(&short) {
            Err(CommError::Decode(DecodeError::Truncated { .. }))
            | Err(CommError::Decode(DecodeError::InvalidCode { .. })) => {}
            other => panic!("truncation at {cut}/{n} must be a decode error, got {other:?}"),
        }
    });
}

#[test]
fn identity_truncation_is_a_decode_error() {
    for_cases(20, 0x1D7, |g| {
        let n = g.usize_in(1, 128);
        let v = g.vec_f64(n, 1.0);
        let mut c = IdentityCompressor;
        let packet = c.encode(&v);
        let cut = g.usize_in(0, packet.len_bits() - 1);
        let short = WirePacket::from_raw(
            mutate_payload(packet.payload(), cut, None),
            packet.layer_offsets().to_vec(),
            n,
        );
        assert!(
            matches!(
                c.decode(&short),
                Err(CommError::Decode(DecodeError::Truncated { .. }))
            ),
            "cut {cut}"
        );
    });
}

#[test]
fn bit_flipped_streams_never_panic() {
    // a single flipped wire bit may still decode (huffman may resynchronize
    // onto a valid parse) — the contract is weaker but absolute: decode
    // returns Ok with the right shape or a CommError, and never panics
    for_cases(80, 0xF11B, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let v = g.vec_f64(map.dim, 1.0);
        let packet = codec.encode(&v);
        let n = packet.len_bits();
        let flip = g.usize_in(0, n - 1);
        let flipped = WirePacket::from_raw(
            mutate_payload(packet.payload(), n, Some(flip)),
            packet.layer_offsets().to_vec(),
            map.dim,
        );
        match codec.decode(&flipped) {
            Ok(out) => {
                // a flipped norm-header bit can legally yield inf/NaN
                // values — the guarantee is shape and no panic, not fidelity
                assert_eq!(out.len(), map.dim);
            }
            Err(CommError::Decode(_))
            | Err(CommError::TrailingBits { .. })
            | Err(CommError::DimMismatch { .. }) => {}
        }
    });
}

#[test]
fn garbage_streams_never_panic() {
    // pure noise presented as a packet: decode must fail (or produce a
    // correctly-shaped vector), never panic — the regression guard for the
    // old `panic!("corrupt huffman stream")`
    for_cases(60, 0x6A12BA6E, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let nbits = g.usize_in(1, 4096);
        let mut w = BitWriter::new();
        let mut left = nbits;
        while left > 0 {
            let take = left.min(64) as u32;
            w.write_bits(g.rng.next_u64(), take);
            left -= take as usize;
        }
        let junk = WirePacket::from_raw(w.finish(), vec![0], map.dim);
        if let Ok(out) = codec.decode(&junk) {
            assert_eq!(out.len(), map.dim);
        }
    });
}

#[test]
fn dim_mismatch_is_always_rejected() {
    for_cases(20, 0xD1A, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let v = g.vec_f64(map.dim, 1.0);
        let packet = codec.encode(&v);
        let wrong = WirePacket::from_raw(
            packet.payload().clone(),
            packet.layer_offsets().to_vec(),
            map.dim + g.usize_in(1, 64),
        );
        assert!(matches!(
            codec.decode(&wrong),
            Err(CommError::DimMismatch { .. })
        ));
    });
}

//! Property/fuzz tests for the comm codec: `WirePacket` encode → decode
//! roundtrips over randomized layer shapes, level widths and protocols, and
//! adversarial wire bytes (truncations, bit flips) that must surface
//! `CommError`/`DecodeError` — never a panic. This is the standing
//! regression guard for the old `panic!("corrupt huffman stream")`: decode
//! operates on untrusted wire data and is fallible end to end.
//!
//! The suite also pins the **fused** single-pass ENC/DEC kernels to the
//! staged reference pipeline: over randomized shapes, level widths,
//! protocols, adaptation schedules and encode-thread counts, both paths
//! must produce bit-identical packets, bit-identical decoded vectors, and
//! — on corrupted input — the *same* `CommError` (same variant, same bit
//! position), so the batched decoder can never mask or shift a failure.
//!
//! The sharded-collective mechanics ride the same harness: shard-then-
//! concat partial decodes must be bit-identical to the full decode over
//! random ownership partitions, and malformed shard requests (reversed,
//! out-of-range, misaligned windows) must surface typed errors, never
//! panic.
//!
//! Uses the in-tree seeded property harness (`qoda::util::prop`) — the
//! environment is offline, no proptest; every failing case reports its
//! replayable seed.

use qoda::coding::bitio::{BitBuf, BitWriter};
use qoda::coding::protocol::ProtocolKind;
use qoda::coding::DecodeError;
use qoda::comm::{
    Adaptation, CommError, Compressor, IdentityCompressor, QuantCompressor, WirePacket,
};
use qoda::coordinator::collectives::assign_layers_by_bits;
use qoda::quant::layer_map::LayerMap;
use qoda::quant::QuantConfig;
use qoda::util::prop::{for_cases, Gen};

/// Random heterogeneous layer map: 1–4 layers, each its own type, sizes
/// 8–300 coordinates.
fn random_map(g: &mut Gen) -> LayerMap {
    let n_layers = g.usize_in(1, 4);
    let spec: Vec<(String, usize, String)> = (0..n_layers)
        .map(|i| (format!("l{i}"), g.usize_in(8, 300), format!("t{i}")))
        .collect();
    let spec_ref: Vec<(&str, usize, &str)> =
        spec.iter().map(|(n, len, ty)| (n.as_str(), *len, ty.as_str())).collect();
    LayerMap::from_spec(&spec_ref)
}

/// Randomized codec parameters, kept separate from the codec so the fused
/// and staged twins can be constructed from identical state.
struct CodecParams {
    bits: u32,
    protocol: ProtocolKind,
    adaptation: Adaptation,
    seed: u64,
    threads: usize,
}

impl CodecParams {
    fn random(g: &mut Gen) -> Self {
        let bits = g.usize_in(2, 7) as u32;
        let protocol = if g.f64_in(0.0, 1.0) < 0.5 {
            ProtocolKind::Main
        } else {
            ProtocolKind::Alternating
        };
        let adaptation = match g.usize_in(0, 2) {
            0 => Adaptation::Fixed,
            1 => Adaptation::Levels { every: 2 },
            _ => Adaptation::LGreco {
                every: 2,
                budget_bits_per_coord: (bits + 1) as f64,
                max_bits: 6,
            },
        };
        CodecParams {
            bits,
            protocol,
            adaptation,
            seed: g.rng.next_u64(),
            threads: [1, 2, 4][g.usize_in(0, 2)],
        }
    }

    fn build(&self, map: &LayerMap, staged: bool) -> QuantCompressor {
        let cfg = QuantConfig::uniform_bits(map.num_types(), self.bits, 2.0);
        let mut c = QuantCompressor::new(
            map.clone(),
            cfg,
            self.protocol,
            self.adaptation.clone(),
            self.seed,
        );
        c.encode_threads = self.threads;
        c.staged = staged;
        c
    }
}

fn random_codec(g: &mut Gen, map: &LayerMap) -> QuantCompressor {
    let bits = g.usize_in(2, 7) as u32;
    let protocol = if g.f64_in(0.0, 1.0) < 0.5 {
        ProtocolKind::Main
    } else {
        ProtocolKind::Alternating
    };
    let cfg = QuantConfig::uniform_bits(map.num_types(), bits, 2.0);
    let seed = g.rng.next_u64();
    QuantCompressor::new(map.clone(), cfg, protocol, Adaptation::Fixed, seed)
}

/// Copy `payload`, optionally truncating to `keep_bits` and XOR-flipping
/// the bit at `flip` (if given). Pure bit plumbing via the public reader.
fn mutate_payload(
    payload: &BitBuf,
    keep_bits: usize,
    flip: Option<usize>,
) -> BitBuf {
    let mut r = payload.reader();
    let mut w = BitWriter::new();
    let mut pos = 0usize;
    while pos < keep_bits {
        let take = (keep_bits - pos).min(64) as u32;
        let mut word = r.read_bits(take);
        if let Some(f) = flip {
            if f >= pos && f < pos + take as usize {
                word ^= 1u64 << (f - pos);
            }
        }
        w.write_bits(word, take);
        pos += take as usize;
    }
    w.finish()
}

#[test]
fn fused_and_staged_streams_are_bit_identical() {
    // the central fusion property: over random shapes, widths, protocols,
    // adaptation schedules and thread counts, the fused one-pass kernels
    // and the staged reference produce the same packets, the same decoded
    // f64 bits, the same wire accounting — across update boundaries
    for_cases(40, 0xF05ED, |g| {
        let map = random_map(g);
        let p = CodecParams::random(g);
        let mut fused = p.build(&map, false);
        let mut staged = p.build(&map, true);
        for step in 0..5 {
            let scale = g.f64_in(0.05, 8.0);
            let v = g.vec_f64(map.dim, scale);
            let pf = fused.encode(&v).expect("fused encode");
            let ps = staged.encode(&v).expect("staged encode");
            assert_eq!(pf.payload(), ps.payload(), "payload diverged at step {step}");
            assert_eq!(pf.layer_offsets(), ps.layer_offsets(), "offsets at step {step}");
            assert_eq!(pf.len_bits(), ps.len_bits());
            let df = fused.decode(&pf).expect("fused decode");
            let ds = staged.decode(&ps).expect("staged decode");
            assert_eq!(df.len(), ds.len());
            for (i, (a, b)) in df.iter().zip(&ds).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "coord {i} at step {step}");
            }
            // cross-decode: each path reads the other's packet
            let cross = staged.decode(&pf).expect("staged decodes fused packet");
            assert_eq!(cross, df);
        }
        assert_eq!(fused.total_bits, staged.total_bits);
        assert_eq!(fused.total_coords, staged.total_coords);
    });
}

#[test]
fn quantized_roundtrip_over_random_shapes_and_levels() {
    for_cases(60, 0xC0DEC, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let scale = g.f64_in(0.05, 8.0);
        let v = g.vec_f64(map.dim, scale);
        let packet = codec.encode(&v).expect("encode");
        // the packet frames the stream: one offset per layer, inside the
        // payload, starting at 0, strictly increasing
        assert_eq!(packet.dim(), map.dim);
        assert_eq!(packet.layer_offsets().len(), map.layers.len());
        assert_eq!(packet.layer_offsets()[0], 0);
        for w in packet.layer_offsets().windows(2) {
            assert!(w[0] < w[1], "offsets must increase: {:?}", packet.layer_offsets());
        }
        assert!(packet.len_bits() > 0);
        // decode reconstructs the exact dimensionality, all finite
        let out = codec.decode(&packet).expect("roundtrip decode");
        assert_eq!(out.len(), map.dim);
        assert!(out.iter().all(|x| x.is_finite()));
        // unbiased-ish reconstruction: positively correlated with the input
        let dot: f64 = v.iter().zip(&out).map(|(a, b)| a * b).sum();
        let norm: f64 = v.iter().map(|a| a * a).sum();
        assert!(dot > -0.25 * norm, "reconstruction anti-correlated: {dot} vs {norm}");
    });
}

#[test]
fn identity_roundtrip_is_exact_f32() {
    for_cases(30, 0x1DE27, |g| {
        let n = g.usize_in(1, 400);
        let v = g.vec_f64(n, 3.0);
        let mut c = IdentityCompressor::new();
        let packet = c.encode(&v).expect("encode");
        assert_eq!(packet.len_bits(), 32 * n);
        let out = c.decode(&packet).expect("identity decode");
        let want: Vec<f64> = v.iter().map(|&x| x as f32 as f64).collect();
        assert_eq!(out, want);
    });
}

#[test]
fn truncated_streams_error_identically_on_both_paths() {
    for_cases(60, 0x7213C, |g| {
        let map = random_map(g);
        let p = CodecParams::random(g);
        let mut fused = p.build(&map, false);
        let mut staged = p.build(&map, true);
        let v = g.vec_f64(map.dim, 1.0);
        let packet = fused.encode(&v).expect("encode");
        let n = packet.len_bits();
        // any strict prefix must fail during decode: the full stream is
        // consumed exactly on success, so fewer bits always run dry
        let cut = g.usize_in(0, n - 1);
        let short = WirePacket::from_raw(
            mutate_payload(packet.payload(), cut, None),
            packet.layer_offsets().to_vec(),
            map.dim,
        );
        let ef = fused.decode(&short);
        let es = staged.decode(&short);
        match &ef {
            Err(CommError::Decode(DecodeError::Truncated { .. }))
            | Err(CommError::Decode(DecodeError::InvalidCode { .. })) => {}
            other => panic!("truncation at {cut}/{n} must be a decode error, got {other:?}"),
        }
        // the batched bit cache must report the same error at the same bit
        // position as the bit-by-bit reference
        assert_eq!(ef.unwrap_err(), es.unwrap_err(), "cut {cut}/{n}");
    });
}

#[test]
fn identity_truncation_is_a_decode_error() {
    for_cases(20, 0x1D7, |g| {
        let n = g.usize_in(1, 128);
        let v = g.vec_f64(n, 1.0);
        let mut c = IdentityCompressor::new();
        let packet = c.encode(&v).expect("encode");
        let cut = g.usize_in(0, packet.len_bits() - 1);
        let short = WirePacket::from_raw(
            mutate_payload(packet.payload(), cut, None),
            packet.layer_offsets().to_vec(),
            n,
        );
        assert!(
            matches!(
                c.decode(&short),
                Err(CommError::Decode(DecodeError::Truncated { .. }))
            ),
            "cut {cut}"
        );
    });
}

#[test]
fn bit_flipped_streams_never_panic_and_paths_agree() {
    // a single flipped wire bit may still decode (huffman may resynchronize
    // onto a valid parse) — the contract is weaker but absolute: decode
    // returns Ok with the right shape or a CommError, never panics, and the
    // fused path reaches the exact same outcome as the staged reference
    for_cases(80, 0xF11B, |g| {
        let map = random_map(g);
        let p = CodecParams::random(g);
        let mut fused = p.build(&map, false);
        let mut staged = p.build(&map, true);
        let v = g.vec_f64(map.dim, 1.0);
        let packet = fused.encode(&v).expect("encode");
        let n = packet.len_bits();
        let flip = g.usize_in(0, n - 1);
        let flipped = WirePacket::from_raw(
            mutate_payload(packet.payload(), n, Some(flip)),
            packet.layer_offsets().to_vec(),
            map.dim,
        );
        let rf = fused.decode(&flipped);
        let rs = staged.decode(&flipped);
        match (&rf, &rs) {
            (Ok(of), Ok(os)) => {
                // a flipped norm-header bit can legally yield inf/NaN
                // values — the guarantee is shape and no panic, not fidelity
                assert_eq!(of.len(), map.dim);
                for (a, b) in of.iter().zip(os) {
                    assert_eq!(a.to_bits(), b.to_bits(), "flip {flip}");
                }
            }
            (Err(ef), Err(es)) => assert_eq!(ef, es, "flip {flip}"),
            other => panic!("paths disagree on flip {flip}: {other:?}"),
        }
    });
}

#[test]
fn garbage_streams_never_panic() {
    // pure noise presented as a packet: decode must fail (or produce a
    // correctly-shaped vector), never panic — the regression guard for the
    // old `panic!("corrupt huffman stream")` — and both decode paths agree
    for_cases(60, 0x6A12BA6E, |g| {
        let map = random_map(g);
        let p = CodecParams::random(g);
        let mut fused = p.build(&map, false);
        let mut staged = p.build(&map, true);
        let nbits = g.usize_in(1, 4096);
        let mut w = BitWriter::new();
        let mut left = nbits;
        while left > 0 {
            let take = left.min(64) as u32;
            w.write_bits(g.rng.next_u64(), take);
            left -= take as usize;
        }
        let junk = WirePacket::from_raw(w.finish(), vec![0], map.dim);
        let rf = fused.decode(&junk);
        let rs = staged.decode(&junk);
        match (&rf, &rs) {
            (Ok(of), Ok(os)) => {
                assert_eq!(of.len(), map.dim);
                assert_eq!(of.len(), os.len());
            }
            (Err(ef), Err(es)) => assert_eq!(ef, es),
            other => panic!("paths disagree on garbage: {other:?}"),
        }
    });
}

#[test]
fn shard_decodes_concatenate_bit_identically() {
    // the sharded reduce-scatter correctness property: slice a coded packet
    // at layer boundaries into a random bit-balanced ownership partition,
    // partial-decode every shard, concatenate in range order — the result
    // must match the unsharded decode bit for bit (empty owner ranges
    // included)
    for_cases(60, 0x5A4D, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let v = g.vec_f64(map.dim, g.f64_in(0.05, 4.0));
        let packet = codec.encode(&v).expect("encode");
        let full = codec.decode(&packet).expect("full decode");
        let k = g.usize_in(1, 5);
        let ranges = assign_layers_by_bits(&packet.layer_bits(), k);
        let mut concat: Vec<f64> = Vec::with_capacity(map.dim);
        for &(lo, hi) in &ranges {
            let dim: usize = map.layers[lo..hi].iter().map(|l| l.len).sum();
            let shard = packet.shard(lo..hi, dim).expect("shard");
            let mut out = Vec::with_capacity(dim);
            codec.decode_layers_into(&shard, lo..hi, &mut out).expect("shard decode");
            assert_eq!(out.len(), dim);
            concat.extend_from_slice(&out);
        }
        assert_eq!(concat.len(), full.len());
        for (i, (a, b)) in concat.iter().zip(&full).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "coord {i} diverged under sharding");
        }
    });
}

#[test]
fn bad_shard_requests_error_never_panic() {
    for_cases(40, 0xBAD5, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let v = g.vec_f64(map.dim, 1.0);
        let packet = codec.encode(&v).expect("encode");
        let n = map.layers.len();
        // past-the-end and reversed ranges are typed errors on the packet
        assert!(matches!(
            packet.shard(0..n + 1 + g.usize_in(0, 3), 4),
            Err(CommError::ShardRange { .. })
        ));
        assert!(matches!(packet.shard(2..1, 1), Err(CommError::ShardRange { .. })));
        // a layer-0 shard presented for the wrong window: out-of-range is a
        // ShardRange, a wider window is a DimMismatch — never a panic
        let dim0 = map.layers[0].len;
        let shard = packet.shard(0..1, dim0).expect("shard");
        let mut out = Vec::new();
        assert!(matches!(
            codec.decode_layers_into(&shard, n..n + 2, &mut out),
            Err(CommError::ShardRange { .. })
        ));
        if n >= 2 {
            assert!(matches!(
                codec.decode_layers_into(&shard, 0..n, &mut out),
                Err(CommError::DimMismatch { .. })
            ));
            // misaligned window of the right layer count: either a typed
            // error (mismatched coord count) or a shape-correct decode of
            // the wrong bits (equal-length layers) — both legal, no panic
            let r = codec.decode_layers_into(&shard, 1..2, &mut out);
            if map.layers[1].len != dim0 {
                assert!(matches!(r, Err(CommError::DimMismatch { .. })), "{r:?}");
            }
        }
    });
}

#[test]
fn dim_mismatch_is_always_rejected() {
    for_cases(20, 0xD1A, |g| {
        let map = random_map(g);
        let mut codec = random_codec(g, &map);
        let v = g.vec_f64(map.dim, 1.0);
        let packet = codec.encode(&v).expect("encode");
        let wrong = WirePacket::from_raw(
            packet.payload().clone(),
            packet.layer_offsets().to_vec(),
            map.dim + g.usize_in(1, 64),
        );
        assert!(matches!(
            codec.decode(&wrong),
            Err(CommError::DimMismatch { .. })
        ));
    });
}

//! Integration: the paper's convergence theory holds on the implementation.
//!
//! Theorem 5.5 — GAP = O(1/sqrt(TK)) under absolute noise;
//! Theorem 5.7/6.2 — faster decay under relative noise;
//! Remark 5.8 — more nodes help;
//! Section 4 — QODA halves Q-GenX's oracle calls at comparable GAP.

use qoda::bench_harness::experiments::rate_sweep;
use qoda::vi::noise::NoiseModel;

fn decay_slope(points: &[(usize, f64)]) -> f64 {
    let (t0, g0) = points[0];
    let (t1, g1) = *points.last().unwrap();
    (g1.max(1e-12) / g0.max(1e-12)).ln() / ((t1 as f64) / (t0 as f64)).ln()
}

fn averaged_gaps(
    kind: &str,
    k: usize,
    noise: NoiseModel,
    horizons: &[usize],
    use_alt: bool,
    seeds: u64,
) -> Vec<(usize, f64)> {
    let mut acc = vec![0.0; horizons.len()];
    for s in 0..seeds {
        let pts = rate_sweep(kind, k, noise, Some(6), horizons, 300 + s, use_alt);
        for (a, p) in acc.iter_mut().zip(&pts) {
            *a += p.gap / seeds as f64;
        }
    }
    horizons.iter().copied().zip(acc).collect()
}

#[test]
fn gap_decays_under_absolute_noise() {
    let horizons = [64usize, 512, 4096];
    let pts = averaged_gaps(
        "quadratic",
        2,
        NoiseModel::Absolute { sigma: 0.5 },
        &horizons,
        false,
        3,
    );
    let slope = decay_slope(&pts);
    // Theorem 5.5 predicts ~ -0.5; allow a generous band on a finite run
    assert!(slope < -0.25, "slope {slope}, gaps {pts:?}");
    assert!(pts.last().unwrap().1 < pts[0].1, "{pts:?}");
}

#[test]
fn relative_noise_decays_faster_than_absolute() {
    let horizons = [64usize, 512, 4096];
    let abs = averaged_gaps(
        "quadratic",
        2,
        NoiseModel::Absolute { sigma: 0.5 },
        &horizons,
        false,
        3,
    );
    let rel = averaged_gaps(
        "quadratic",
        2,
        NoiseModel::Relative { sigma_r: 0.5 },
        &horizons,
        false,
        3,
    );
    let s_abs = decay_slope(&abs);
    let s_rel = decay_slope(&rel);
    // Theorem 5.7: O(1/T) vs O(1/sqrt(T)) — the relative-noise slope must be
    // clearly steeper
    assert!(s_rel < s_abs - 0.15, "rel {s_rel} vs abs {s_abs}");
}

#[test]
fn more_nodes_reduce_gap_under_absolute_noise() {
    // Remark 5.8: K in the denominator
    let horizons = [1024usize];
    let g1 = averaged_gaps(
        "quadratic",
        1,
        NoiseModel::Absolute { sigma: 1.0 },
        &horizons,
        false,
        4,
    )[0]
        .1;
    let g8 = averaged_gaps(
        "quadratic",
        8,
        NoiseModel::Absolute { sigma: 1.0 },
        &horizons,
        false,
        4,
    )[0]
        .1;
    assert!(g8 < g1, "K=8 gap {g8} should beat K=1 gap {g1}");
}

#[test]
fn alt_schedule_handles_bilinear_without_cocoercivity() {
    // Theorem 6.2: bilinear games are NOT co-coercive; the (Alt) schedule
    // must still drive the gap down under relative noise
    let horizons = [128usize, 1024, 4096];
    let pts = averaged_gaps(
        "bilinear",
        2,
        NoiseModel::Relative { sigma_r: 0.3 },
        &horizons,
        true,
        3,
    );
    assert!(
        pts.last().unwrap().1 < 0.5 * pts[0].1,
        "no progress on bilinear: {pts:?}"
    );
}

#[test]
fn quantized_matches_uncompressed_rate_shape() {
    // unbiased quantization must not change the decay exponent, only the
    // constant (Theorem 5.5's eps_Q factor)
    let horizons = [64usize, 512, 4096];
    let mut q = vec![0.0; horizons.len()];
    let mut u = vec![0.0; horizons.len()];
    for s in 0..3 {
        let pq = rate_sweep(
            "quadratic",
            2,
            NoiseModel::Absolute { sigma: 0.5 },
            Some(5),
            &horizons,
            500 + s,
            false,
        );
        let pu = rate_sweep(
            "quadratic",
            2,
            NoiseModel::Absolute { sigma: 0.5 },
            None,
            &horizons,
            500 + s,
            false,
        );
        for i in 0..horizons.len() {
            q[i] += pq[i].gap / 3.0;
            u[i] += pu[i].gap / 3.0;
        }
    }
    let sq = decay_slope(&horizons.iter().copied().zip(q.clone()).collect::<Vec<_>>());
    let su = decay_slope(&horizons.iter().copied().zip(u.clone()).collect::<Vec<_>>());
    assert!((sq - su).abs() < 0.35, "slopes diverge: quant {sq} vs raw {su}");
    // constant-factor penalty bounded (eps_Q at 5 bits is small)
    assert!(q.last().unwrap() < &(u.last().unwrap() * 6.0 + 1e-6));
}

//! Integration: the full distributed stack — threaded coordinator vs
//! deterministic sim engine driving the *same* `comm` wire pipeline
//! (bit-identical aggregates and identical wire bit counts across both
//! protocols and multiple seeds), plus native-model WGAN/LM short training
//! runs.

use qoda::coding::protocol::ProtocolKind;
use qoda::comm::{Adaptation, Compressor};
use qoda::coordinator::parallel::{
    run_rounds, worker_codec_seed, worker_oracle_seed, SharedQuantState,
};
use qoda::coordinator::sim::ClusterSim;
use qoda::gan::trainer::{self as gan_trainer, GanCompression, GanOptimizer, GanTrainConfig};
use qoda::lm::trainer::{self as lm_trainer, LmTrainConfig};
use qoda::net::NetworkModel;
use qoda::quant::layer_map::LayerMap;
use qoda::quant::{LevelSequence, QuantConfig};
use qoda::runtime::{LmModel, Runtime, WganModel};
use qoda::stats::rng::Rng;
use qoda::vi::noise::{NoiseModel, Oracle};
use qoda::vi::operator::QuadraticOperator;

#[test]
fn threaded_coordinator_trains_distributed_sgd() {
    let mut rng = Rng::new(1);
    let op = QuadraticOperator::random(32, 0.5, &mut rng);
    let st = SharedQuantState {
        map: LayerMap::single(32).bucketed(16),
        cfg: QuantConfig::same(1, LevelSequence::bits(5), 2.0),
        protocol: ProtocolKind::Main,
        adaptation: Adaptation::Fixed,
    };
    let (x, bits, _) = run_rounds(
        &op,
        NoiseModel::Absolute { sigma: 0.2 },
        6,
        &st,
        vec![0.0; 32],
        500,
        11,
        |x, mean, _t| {
            for (xi, g) in x.iter_mut().zip(mean) {
                *xi -= 0.05 * g;
            }
        },
    )
    .expect("run_rounds");
    let err: f64 = x
        .iter()
        .zip(&op.sol)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let scale: f64 = op.sol.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(err < 0.25 * scale, "err {err} vs {scale}");
    // wire accounting: ~6.x bits/coord (5-bit symbols + signs + norms)
    let bits_per_coord = bits as f64 / (500.0 * 6.0 * 32.0);
    assert!(bits_per_coord < 12.0, "{bits_per_coord}");
}

/// The acceptance test of the unified pipeline: the threaded engine and the
/// sim engine, driven by the same seeds through the same `comm` codecs,
/// must produce bit-identical aggregates, identical final iterates AND
/// identical total wire bit counts — for both coding protocols and several
/// seeds.
#[test]
fn sim_and_parallel_agree_bitwise_across_protocols_and_seeds() {
    let d = 24;
    let k = 3;
    let steps = 4;
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let mut op_rng = Rng::new(99);
    let op = QuadraticOperator::random(d, 0.5, &mut op_rng);
    let lr = 0.07;

    for protocol in [ProtocolKind::Main, ProtocolKind::Alternating] {
        for seed in [11u64, 29, 47] {
            let st = SharedQuantState {
                map: LayerMap::from_spec(&[("a", 16, "ff"), ("b", 8, "emb")]).bucketed(8),
                cfg: QuantConfig {
                    sequences: vec![LevelSequence::bits(4), LevelSequence::bits(6)],
                    q: 2.0,
                },
                protocol,
                adaptation: Adaptation::Fixed,
            };
            let x0 = vec![0.3; d];

            // threaded engine
            let (x_par, bits_par, mean_par) = run_rounds(
                &op,
                noise,
                k,
                &st,
                x0.clone(),
                steps,
                seed,
                |x, mean, _| {
                    for (xi, g) in x.iter_mut().zip(mean) {
                        *xi -= lr * g;
                    }
                },
            )
            .expect("run_rounds");

            // sim engine with the same per-node codec + oracle seeds
            let codecs: Vec<Box<dyn Compressor>> = (0..k)
                .map(|n| Box::new(st.codec(worker_codec_seed(seed, n))) as _)
                .collect();
            let mut sim = ClusterSim::new(codecs, NetworkModel::genesis_cloud(5.0), false);
            let mut oracles: Vec<Oracle> = (0..k)
                .map(|n| Oracle::new(&op, noise, worker_oracle_seed(seed, n)))
                .collect();
            let mut x = x0;
            let mut bits_sim = 0u64;
            let mut last_mean = vec![0.0; d];
            for _ in 0..steps {
                let duals: Vec<Vec<f64>> =
                    oracles.iter_mut().map(|o| o.sample(&x)).collect();
                let (mean, m) = sim.exchange(&duals).expect("exchange");
                bits_sim += m.wire_bits;
                for (xi, g) in x.iter_mut().zip(&mean) {
                    *xi -= lr * g;
                }
                last_mean = mean;
            }

            assert_eq!(
                mean_par, last_mean,
                "aggregate mismatch ({protocol:?}, seed {seed})"
            );
            assert_eq!(x_par, x, "iterate mismatch ({protocol:?}, seed {seed})");
            assert_eq!(
                bits_par, bits_sim,
                "wire bit count mismatch ({protocol:?}, seed {seed})"
            );
            assert!(bits_par > 0);
        }
    }
}

#[test]
fn sim_engine_full_gan_loop_runs_and_improves_fid() {
    let rt = Runtime::cpu().unwrap();
    let model = WganModel::load(&rt).unwrap();
    let cfg = GanTrainConfig {
        optimizer: GanOptimizer::OptimisticAdam,
        compression: GanCompression::LayerwiseLGreco { bits: 5, bucket: 128, every: 30 },
        k_nodes: 2,
        steps: 80,
        fid_every: 20,
        seed: 3,
        ..Default::default()
    };
    let run = gan_trainer::train(&model, &cfg).unwrap();
    assert_eq!(run.fid_curve.len(), 4);
    let first = run.fid_curve[0].1;
    assert!(
        run.final_fid < first,
        "FID should improve: {first} -> {}",
        run.final_fid
    );
    // compressed wire: at 5 bits + overheads, well under fp32
    let mean_bytes = run.metrics.steps.iter().map(|m| m.bytes_per_node).sum::<f64>()
        / run.metrics.steps.len() as f64;
    assert!(mean_bytes < (model.dim * 4) as f64 / 3.0, "{mean_bytes}");
}

#[test]
fn gan_overlapped_exchange_trains_and_hides_comm() {
    use qoda::coordinator::ExchangeMode;
    let rt = Runtime::cpu().unwrap();
    let model = WganModel::load(&rt).unwrap();
    let cfg = GanTrainConfig {
        optimizer: GanOptimizer::OptimisticAdam,
        compression: GanCompression::Global { bits: 5, bucket: 128 },
        k_nodes: 2,
        steps: 60,
        fid_every: 30,
        seed: 7,
        exchange: ExchangeMode::Overlapped { depth: 1 },
        ..Default::default()
    };
    let run = gan_trainer::train(&model, &cfg).unwrap();
    assert!(run.final_fid.is_finite());
    assert!(run.params.iter().all(|p| p.is_finite()));
    assert_eq!(run.metrics.steps.len(), 60);
    for m in &run.metrics.steps {
        // measured compute > 0 and modeled comm > 0 => some comm hides.
        // (The split is steady-state accounting — the drain tail's comm is
        // charged as if the pipeline were full; see ExchangePlan::split.)
        assert!(m.comm_hidden_s > 0.0, "step {}", m.step);
        let split = m.comm_exposed_s + m.comm_hidden_s;
        assert!((split - m.comm_s).abs() <= 1e-12 * m.comm_s, "step {}", m.step);
        assert!(m.wall_s() < m.total_s());
    }
    // the stale-aggregate path must still optimize (same ballpark band the
    // compression-equivalence test uses — one-step staleness is a delay,
    // not divergence)
    let first = run.fid_curve[0].1;
    assert!(
        run.final_fid < first * 3.0 + 0.5,
        "overlapped training diverged: {first} -> {}",
        run.final_fid
    );
}

#[test]
fn gan_uncompressed_and_compressed_reach_similar_fid() {
    // the unbiased-compression promise: same hyperparameters, comparable
    // convergence (paper: "recovers the baseline accuracy")
    let rt = Runtime::cpu().unwrap();
    let model = WganModel::load(&rt).unwrap();
    let mut fids = Vec::new();
    for compression in [
        GanCompression::None,
        GanCompression::Global { bits: 5, bucket: 128 },
    ] {
        let cfg = GanTrainConfig {
            optimizer: GanOptimizer::OptimisticAdam,
            compression,
            k_nodes: 2,
            steps: 80,
            fid_every: 40,
            seed: 5,
            ..Default::default()
        };
        let run = gan_trainer::train(&model, &cfg).unwrap();
        fids.push(run.final_fid);
    }
    // quantized run lands in the same ballpark (within 3x on this tiny run)
    assert!(
        fids[1] < fids[0] * 3.0 + 0.5,
        "uncompressed {} vs quantized {}",
        fids[0],
        fids[1]
    );
}

#[test]
fn lm_training_reduces_perplexity_vs_init() {
    let rt = Runtime::cpu().unwrap();
    let model = LmModel::load(&rt).unwrap();
    let cfg = LmTrainConfig {
        rank: 8,
        quant_bits: Some(4),
        layerwise: true,
        k_nodes: 2,
        steps: 40,
        eval_every: 20,
        seed: 2,
        ..Default::default()
    };
    let run = lm_trainer::train(&model, &cfg).unwrap();
    let uniform_ppl = model.vocab as f64;
    assert!(
        run.final_ppl < 0.8 * uniform_ppl,
        "ppl {} vs uniform {uniform_ppl}",
        run.final_ppl
    );
    assert!(run.compression_rate > 2.0, "{}", run.compression_rate);
    // training loss decreased
    let first = run.loss_curve.first().unwrap().1;
    let last = run.loss_curve.last().unwrap().1;
    assert!(last < first, "{first} -> {last}");
}

#[test]
fn cluster_sim_level_updates_do_not_break_training() {
    let map = LayerMap::from_spec(&[("a", 512, "ff"), ("b", 256, "embedding")]);
    let comps: Vec<Box<dyn Compressor>> = (0..3)
        .map(|i| Box::new(qoda::comm::QuantCompressor::layerwise(&map, 4, 1 << 20, 7, 50 + i)) as _)
        .collect();
    let mut sim = ClusterSim::new(comps, NetworkModel::genesis_cloud(5.0), false);
    let mut rng = Rng::new(9);
    for step in 0..25 {
        let duals: Vec<Vec<f64>> = (0..3)
            .map(|_| {
                (0..768)
                    .map(|i| rng.gaussian() * if i < 512 { 1.0 } else { 20.0 })
                    .collect()
            })
            .collect();
        let (mean, m) = sim.exchange(&duals).unwrap();
        assert!(mean.iter().all(|x| x.is_finite()), "step {step}");
        assert!(m.bytes_per_node > 0.0);
        assert_eq!(m.wire_bits as f64, m.bytes_per_node * 3.0 * 8.0);
        if step == 10 {
            sim.update_levels();
        }
    }
}

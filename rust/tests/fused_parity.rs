//! Golden parity for the fused ENC/DEC hot path: over the full grid of
//! wire protocols × adaptation modes × seeds × encode-thread counts, the
//! fused single-pass kernels (`coding::fused`, the default) and the staged
//! reference pipeline (`QuantCompressor::staged = true`) must produce
//!
//! * bit-identical wire packets (payload, layer offsets, bit count),
//! * bit-identical decoded `f64` vectors (including cross-decode of each
//!   other's packets),
//! * identical wire accounting (`total_bits`, `total_coords`) and
//!   identical adaptive state trajectories (`current_eps_q` after updates),
//!
//! across a multi-step run that crosses adaptation-update boundaries. This
//! is the contract that makes every fused-path optimization falsifiable:
//! the staged pipeline is the specification, the fused pipeline is the
//! implementation, and the wire format is pinned to both.

use qoda::coding::protocol::ProtocolKind;
use qoda::comm::{Adaptation, Compressor, QuantCompressor};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::QuantConfig;
use qoda::stats::rng::Rng;

/// Transformer-flavored heterogeneous map: three layer types, bucketed to
/// ten layers so every thread count in the grid takes its parallel path.
fn parity_map() -> LayerMap {
    LayerMap::from_spec(&[("ff", 700, "ff"), ("emb", 300, "embedding"), ("b", 65, "bias")])
        .bucketed(128)
}

fn grad_like(map: &LayerMap, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..map.dim)
        .map(|i| rng.gaussian() * if i % 3 == 0 { 2.0 } else { 0.05 })
        .collect()
}

fn build(
    map: &LayerMap,
    protocol: ProtocolKind,
    adaptation: &Adaptation,
    seed: u64,
    threads: usize,
    staged: bool,
) -> QuantCompressor {
    let cfg = QuantConfig::uniform_bits(map.num_types(), 5, 2.0);
    let mut c =
        QuantCompressor::new(map.clone(), cfg, protocol, adaptation.clone(), seed);
    c.encode_threads = threads;
    c.staged = staged;
    c
}

fn adaptations() -> Vec<Adaptation> {
    vec![
        Adaptation::Fixed,
        Adaptation::Levels { every: 2 },
        Adaptation::LGreco { every: 2, budget_bits_per_coord: 6.0, max_bits: 6 },
    ]
}

/// The full grid: both protocols, all three adaptation modes, three seeds,
/// three thread counts, seven steps (crossing the `every = 2` update
/// boundary three times).
#[test]
fn fused_matches_staged_across_the_full_grid() {
    let map = parity_map();
    assert!(map.layers.len() >= 8, "grid needs the 4-thread parallel path");
    for protocol in [ProtocolKind::Main, ProtocolKind::Alternating] {
        for adaptation in adaptations() {
            for seed in [1u64, 42, 977] {
                for threads in [1usize, 2, 4] {
                    let mut fused =
                        build(&map, protocol, &adaptation, seed, threads, false);
                    let mut staged =
                        build(&map, protocol, &adaptation, seed, threads, true);
                    let tag = format!(
                        "{protocol:?}/{adaptation:?}/seed={seed}/threads={threads}"
                    );
                    for step in 0..7 {
                        let v = grad_like(&map, 1000 + 31 * seed + step);
                        let pf = fused.encode(&v).expect("fused encode");
                        let ps = staged.encode(&v).expect("staged encode");
                        assert_eq!(
                            pf.payload(),
                            ps.payload(),
                            "payload diverged: {tag} step {step}"
                        );
                        assert_eq!(
                            pf.layer_offsets(),
                            ps.layer_offsets(),
                            "offsets diverged: {tag} step {step}"
                        );
                        assert_eq!(pf.len_bits(), ps.len_bits());
                        let df = fused.decode(&pf).expect("fused decode");
                        let ds = staged.decode(&ps).expect("staged decode");
                        assert_eq!(df.len(), ds.len());
                        for (i, (a, b)) in df.iter().zip(&ds).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "coord {i} diverged: {tag} step {step}"
                            );
                        }
                        // cross-decode: each pipeline reads the other's bits
                        let xf = staged.decode(&pf).expect("staged reads fused");
                        let xs = fused.decode(&ps).expect("fused reads staged");
                        assert_eq!(xf, df, "{tag} step {step}");
                        assert_eq!(xs, ds, "{tag} step {step}");
                    }
                    assert_eq!(fused.total_bits, staged.total_bits, "{tag}");
                    assert_eq!(fused.total_coords, staged.total_coords, "{tag}");
                    assert_eq!(
                        fused.current_eps_q.to_bits(),
                        staged.current_eps_q.to_bits(),
                        "adaptive trajectory diverged: {tag}"
                    );
                }
            }
        }
    }
}

/// Explicit codebook retuning (the lightweight half of an update step) must
/// leave both paths on the same retuned books.
#[test]
fn retuned_books_keep_parity() {
    let map = parity_map();
    for protocol in [ProtocolKind::Main, ProtocolKind::Alternating] {
        let mut fused = build(&map, protocol, &Adaptation::Fixed, 7, 1, false);
        let mut staged = build(&map, protocol, &Adaptation::Fixed, 7, 1, true);
        let v = grad_like(&map, 555);
        let _ = fused.encode(&v).expect("warm fused");
        let _ = staged.encode(&v).expect("warm staged");
        fused.retune_books();
        staged.retune_books();
        let v2 = grad_like(&map, 556);
        let pf = fused.encode(&v2).expect("fused encode");
        let ps = staged.encode(&v2).expect("staged encode");
        assert_eq!(pf.payload(), ps.payload(), "{protocol:?}");
        assert_eq!(fused.decode(&pf).unwrap(), staged.decode(&ps).unwrap());
    }
}

/// All-zero layers take the no-draw path on both pipelines: same stream,
/// same decoded zeros, same RNG trajectory afterwards (pinned by the next
/// non-zero step still matching).
#[test]
fn zero_vectors_keep_parity_and_rng_alignment() {
    let map = parity_map();
    let mut fused = build(&map, ProtocolKind::Main, &Adaptation::Fixed, 9, 2, false);
    let mut staged = build(&map, ProtocolKind::Main, &Adaptation::Fixed, 9, 2, true);
    let zeros = vec![0.0; map.dim];
    let pf = fused.encode(&zeros).expect("fused encode");
    let ps = staged.encode(&zeros).expect("staged encode");
    assert_eq!(pf.payload(), ps.payload());
    let df = fused.decode(&pf).expect("fused decode");
    assert!(df.iter().all(|&x| x == 0.0));
    assert_eq!(df, staged.decode(&ps).expect("staged decode"));
    // a zero step consumes no randomness on either path: the next real
    // packet still matches bit-for-bit
    let v = grad_like(&map, 777);
    let pf2 = fused.encode(&v).expect("fused encode");
    let ps2 = staged.encode(&v).expect("staged encode");
    assert_eq!(pf2.payload(), ps2.payload());
}

/// Mixed-path exchange: a cluster where some nodes run fused and some run
/// staged codecs stays coherent. All nodes observe the same duals (which is
/// what keeps adaptive state synchronized without shipping codebooks), each
/// encodes with its own RNG seed, and every node must decode every packet
/// to the same bits — the aggregate is independent of which pipeline
/// produced or consumed the stream, across scheduled update boundaries.
#[test]
fn mixed_fused_staged_cluster_agrees() {
    let map = parity_map();
    let mut nodes: Vec<QuantCompressor> = (0..4)
        .map(|i| {
            build(&map, ProtocolKind::Main, &Adaptation::Levels { every: 2 }, 100 + i, 1, i % 2 == 1)
        })
        .collect();
    for step in 0..5 {
        let v = grad_like(&map, 2000 + step);
        let packets: Vec<_> =
            nodes.iter_mut().map(|n| n.encode(&v).expect("encode")).collect();
        // every node decodes every packet identically
        for packet in &packets {
            let mut want: Option<Vec<f64>> = None;
            for n in nodes.iter_mut() {
                let got = n.decode(packet).expect("decode");
                match &want {
                    None => want = Some(got),
                    Some(w) => {
                        for (a, b) in w.iter().zip(&got) {
                            assert_eq!(a.to_bits(), b.to_bits(), "step {step}");
                        }
                    }
                }
            }
        }
    }
}

//! Golden parity: the step-wise `Solver` + `RunDriver` redesign must
//! reproduce the legacy monolithic `run()` loops bit-for-bit — identical
//! `total_bits`, `oracle_calls`, `xbar`, `x_last` and per-checkpoint
//! records on fixed seeds, for QODA, Q-GenX and both Adam baselines.
//!
//! The legacy loops are replicated here verbatim (same operation order,
//! same scratch discipline) on top of the public comm/lr/source APIs, so
//! any drift in the driver's accounting or averaging fails loudly.

use qoda::comm::{CommEndpoint, Compressor, IdentityCompressor, QuantCompressor};
use qoda::oda::baseline::{AdamSolver, AdamState, OptimisticAdam};
use qoda::oda::lr::{observe_from_duals, AdaptiveLr, AltLr, LrSchedule};
use qoda::oda::source::{DualSource, OracleSource};
use qoda::oda::{QGenX, Qoda, RunDriver, RunReport};
use qoda::quant::layer_map::LayerMap;
use qoda::stats::rng::Rng;
use qoda::vi::noise::NoiseModel;
use qoda::vi::operator::QuadraticOperator;

/// What the pre-refactor `run()` loops produced.
struct LegacyRun {
    checkpoints: Vec<(usize, Vec<f64>, u64, u64)>,
    xbar: Vec<f64>,
    x_last: Vec<f64>,
    total_bits: u64,
    oracle_calls: u64,
    bits_per_iter_node: f64,
}

fn assert_bit_identical(legacy: &LegacyRun, report: &RunReport) {
    assert_eq!(legacy.total_bits, report.total_bits, "total_bits drifted");
    assert_eq!(legacy.oracle_calls, report.oracle_calls, "oracle_calls drifted");
    assert_eq!(legacy.xbar, report.xbar, "xbar drifted");
    assert_eq!(legacy.x_last, report.x_last, "x_last drifted");
    assert_eq!(legacy.bits_per_iter_node, report.bits_per_iter_node);
    assert_eq!(legacy.checkpoints.len(), report.checkpoints.len());
    for (l, n) in legacy.checkpoints.iter().zip(&report.checkpoints) {
        assert_eq!(l.0, n.t);
        assert_eq!(l.1, n.xbar, "checkpoint xbar drifted at t = {}", n.t);
        assert_eq!(l.2, n.total_bits);
        assert_eq!(l.3, n.oracle_calls);
    }
}

/// The pre-refactor `Qoda::run`, verbatim.
#[allow(clippy::too_many_arguments)]
fn legacy_qoda(
    source: &mut dyn DualSource,
    compressors: Vec<Box<dyn Compressor>>,
    mut lr: Box<dyn LrSchedule>,
    update_every: usize,
    x0: &[f64],
    steps: usize,
    checkpoints: &[usize],
) -> LegacyRun {
    let mut endpoints: Vec<CommEndpoint> =
        compressors.into_iter().map(CommEndpoint::new).collect();
    let d = source.dim();
    let k = source.num_nodes();
    let kf = k as f64;
    let x1 = x0.to_vec();
    let mut x = x0.to_vec();
    let mut y = vec![0.0; d];
    let mut prev_hat: Vec<Vec<f64>> = vec![vec![0.0; d]; k];
    let mut hats: Vec<Vec<f64>> = vec![vec![0.0; d]; k];
    let mut xbar_sum = vec![0.0; d];
    let mut total_bits = 0u64;
    let mut out_ckpts = Vec::new();
    let mut last_dx_sq = 0.0;
    let mut ck_iter = checkpoints.iter().peekable();

    for t in 1..=steps {
        let gamma = lr.gamma();
        let mut x_half = x.clone();
        for kk in 0..k {
            for (xh, v) in x_half.iter_mut().zip(&prev_hat[kk]) {
                *xh -= gamma * v / kf;
            }
        }
        let duals = source.duals(&x_half);
        for (kk, dual) in duals.iter().enumerate() {
            let bits = endpoints[kk]
                .roundtrip_into(dual, &mut hats[kk])
                .expect("comm loopback roundtrip");
            total_bits += bits as u64;
        }
        let (diff_sq, sum_sq, _) = observe_from_duals(&hats, &prev_hat, &x, &x);
        lr.observe(diff_sq, sum_sq, last_dx_sq);
        for kk in 0..k {
            for (yi, v) in y.iter_mut().zip(&hats[kk]) {
                *yi -= v / kf;
            }
        }
        let eta = lr.eta();
        let mut x_next = vec![0.0; d];
        for i in 0..d {
            x_next[i] = x1[i] + eta * y[i];
        }
        last_dx_sq = x
            .iter()
            .zip(&x_next)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        x = x_next;
        std::mem::swap(&mut prev_hat, &mut hats);
        for (s, v) in xbar_sum.iter_mut().zip(&x_half) {
            *s += v;
        }
        if update_every > 0 && t % update_every == 0 {
            for ep in &mut endpoints {
                ep.update_levels();
            }
        }
        if ck_iter.peek() == Some(&&t) {
            ck_iter.next();
            out_ckpts.push((
                t,
                xbar_sum.iter().map(|s| s / t as f64).collect(),
                total_bits,
                source.calls(),
            ));
        }
    }
    LegacyRun {
        checkpoints: out_ckpts,
        xbar: xbar_sum.iter().map(|s| s / steps as f64).collect(),
        x_last: x,
        total_bits,
        oracle_calls: source.calls(),
        bits_per_iter_node: total_bits as f64 / (steps as f64 * kf),
    }
}

/// The pre-refactor `QGenX::run`, verbatim.
fn legacy_qgenx(
    source: &mut dyn DualSource,
    compressors: Vec<Box<dyn Compressor>>,
    mut lr: Box<dyn LrSchedule>,
    x0: &[f64],
    steps: usize,
    checkpoints: &[usize],
) -> LegacyRun {
    let mut endpoints: Vec<CommEndpoint> =
        compressors.into_iter().map(CommEndpoint::new).collect();
    let d = source.dim();
    let k = source.num_nodes();
    let kf = k as f64;
    let mut x = x0.to_vec();
    let mut xbar_sum = vec![0.0; d];
    let mut total_bits = 0u64;
    let mut out_ckpts = Vec::new();
    let mut ck_iter = checkpoints.iter().peekable();
    let mut hat: Vec<f64> = Vec::with_capacity(d);

    for t in 1..=steps {
        let gamma = lr.gamma();
        let duals0 = source.duals(&x);
        let mut mean0 = vec![0.0; d];
        for (kk, dual) in duals0.iter().enumerate() {
            let bits = endpoints[kk]
                .roundtrip_into(dual, &mut hat)
                .expect("comm loopback roundtrip");
            total_bits += bits as u64;
            for (m, v) in mean0.iter_mut().zip(&hat) {
                *m += v / kf;
            }
        }
        let x_half: Vec<f64> =
            x.iter().zip(&mean0).map(|(xi, g)| xi - gamma * g).collect();
        let duals1 = source.duals(&x_half);
        let mut mean1 = vec![0.0; d];
        for (kk, dual) in duals1.iter().enumerate() {
            let bits = endpoints[kk]
                .roundtrip_into(dual, &mut hat)
                .expect("comm loopback roundtrip");
            total_bits += bits as u64;
            for (m, v) in mean1.iter_mut().zip(&hat) {
                *m += v / kf;
            }
        }
        let diff_sq: f64 = mean1
            .iter()
            .zip(&mean0)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        lr.observe(diff_sq, 0.0, 0.0);
        for i in 0..d {
            x[i] -= gamma * mean1[i];
        }
        for (s, v) in xbar_sum.iter_mut().zip(&x_half) {
            *s += v;
        }
        if ck_iter.peek() == Some(&&t) {
            ck_iter.next();
            out_ckpts.push((
                t,
                xbar_sum.iter().map(|s| s / t as f64).collect(),
                total_bits,
                source.calls(),
            ));
        }
    }
    LegacyRun {
        checkpoints: out_ckpts,
        xbar: xbar_sum.iter().map(|s| s / steps as f64).collect(),
        x_last: x,
        total_bits,
        oracle_calls: source.calls(),
        bits_per_iter_node: total_bits as f64 / (steps as f64 * kf),
    }
}

/// The pre-refactor manual Adam loop (`AdamSolver::step` driven by hand),
/// with the iterate average the driver now maintains.
fn legacy_adam(
    source: &mut dyn DualSource,
    compressors: Vec<Box<dyn Compressor>>,
    lr: f64,
    optimistic: bool,
    x0: &[f64],
    steps: usize,
) -> LegacyRun {
    let mut endpoints: Vec<CommEndpoint> =
        compressors.into_iter().map(CommEndpoint::new).collect();
    let d = source.dim();
    let kf = source.num_nodes() as f64;
    let mut adam = AdamState::new(d, lr);
    let mut x = x0.to_vec();
    let mut prev_dir = vec![0.0; d];
    let mut hat: Vec<f64> = Vec::new();
    let mut xbar_sum = vec![0.0; d];
    let mut total_bits = 0u64;

    for _t in 1..=steps {
        let query: Vec<f64> = if optimistic {
            x.iter().zip(prev_dir.iter()).map(|(xi, p)| xi - p).collect()
        } else {
            x.to_vec()
        };
        let duals = source.duals(&query);
        let mut mean = vec![0.0; d];
        for (kk, dual) in duals.iter().enumerate() {
            let bits = endpoints[kk]
                .roundtrip_into(dual, &mut hat)
                .expect("comm loopback roundtrip");
            total_bits += bits as u64;
            for (m, v) in mean.iter_mut().zip(&hat) {
                *m += v / kf;
            }
        }
        let dir = adam.direction(&mean);
        for (xi, di) in x.iter_mut().zip(&dir) {
            *xi -= di;
        }
        prev_dir = dir;
        for (s, v) in xbar_sum.iter_mut().zip(&x) {
            *s += v;
        }
    }
    LegacyRun {
        checkpoints: Vec::new(),
        xbar: xbar_sum.iter().map(|s| s / steps as f64).collect(),
        x_last: x,
        total_bits,
        oracle_calls: source.calls(),
        bits_per_iter_node: total_bits as f64 / (steps as f64 * kf),
    }
}

fn quant_boxes(d: usize, bits: u32, k: usize, seed0: u64) -> Vec<Box<dyn Compressor>> {
    let map = LayerMap::single(d);
    (0..k)
        .map(|i| {
            Box::new(QuantCompressor::global_bits(&map, bits, 128, seed0 + i as u64))
                as Box<dyn Compressor>
        })
        .collect()
}

fn identity_boxes(k: usize) -> Vec<Box<dyn Compressor>> {
    (0..k).map(|_| Box::new(IdentityCompressor::new()) as Box<dyn Compressor>).collect()
}

#[test]
fn qoda_driver_matches_legacy_loop_quantized() {
    let mut rng = Rng::new(5);
    let op = QuadraticOperator::random(16, 0.5, &mut rng);
    let x0 = vec![0.0; 16];
    let cks = [50usize, 150, 300];

    let mut src_a = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.2 }, 6);
    let legacy = legacy_qoda(
        &mut src_a,
        quant_boxes(16, 6, 2, 10),
        Box::new(AdaptiveLr::default()),
        0,
        &x0,
        300,
        &cks,
    );

    let mut src_b = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.2 }, 6);
    let mut solver = Qoda::new(
        &mut src_b,
        quant_boxes(16, 6, 2, 10),
        Box::new(AdaptiveLr::default()),
    );
    let report = RunDriver::new().checkpoints(&cks).run(&mut solver, &x0, 300);
    assert_bit_identical(&legacy, &report);
}

#[test]
fn qoda_driver_matches_legacy_loop_update_steps() {
    // explicit update-step set U exercised: the codecs retune mid-run and
    // the wire bits drift between arms unless the cadence is identical
    let mut rng = Rng::new(7);
    let op = QuadraticOperator::random(12, 0.8, &mut rng);
    let x0 = vec![0.0; 12];

    let mut src_a = OracleSource::new(&op, 3, NoiseModel::Absolute { sigma: 0.3 }, 8);
    let legacy = legacy_qoda(
        &mut src_a,
        quant_boxes(12, 5, 3, 40),
        Box::new(AltLr::new(0.25)),
        25,
        &x0,
        200,
        &[200],
    );

    let mut src_b = OracleSource::new(&op, 3, NoiseModel::Absolute { sigma: 0.3 }, 8);
    let mut solver = Qoda::new(
        &mut src_b,
        quant_boxes(12, 5, 3, 40),
        Box::new(AltLr::new(0.25)),
    );
    solver.update_every = 25;
    let report = RunDriver::new().checkpoints(&[200]).run(&mut solver, &x0, 200);
    assert_bit_identical(&legacy, &report);
}

#[test]
fn qgenx_driver_matches_legacy_loop() {
    let mut rng = Rng::new(9);
    let op = QuadraticOperator::random(16, 0.5, &mut rng);
    let x0 = vec![0.0; 16];
    let cks = [100usize, 250];

    let mut src_a = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.2 }, 12);
    let legacy = legacy_qgenx(
        &mut src_a,
        quant_boxes(16, 5, 2, 20),
        Box::new(AdaptiveLr::default()),
        &x0,
        250,
        &cks,
    );

    let mut src_b = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.2 }, 12);
    let mut solver = QGenX::new(
        &mut src_b,
        quant_boxes(16, 5, 2, 20),
        Box::new(AdaptiveLr::default()),
    );
    let report = RunDriver::new().checkpoints(&cks).run(&mut solver, &x0, 250);
    assert_bit_identical(&legacy, &report);
}

#[test]
fn adam_driver_matches_legacy_loop() {
    let mut rng = Rng::new(11);
    let op = QuadraticOperator::random(8, 0.5, &mut rng);
    let x0 = vec![0.0; 8];

    let mut src_a = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.1 }, 14);
    let legacy =
        legacy_adam(&mut src_a, identity_boxes(2), 0.05, false, &x0, 150);

    let mut src_b = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.1 }, 14);
    let mut solver = AdamSolver::new(&mut src_b, identity_boxes(2), 0.05);
    let report = RunDriver::new().run(&mut solver, &x0, 150);
    assert_bit_identical(&legacy, &report);
}

#[test]
fn optimistic_adam_driver_matches_legacy_loop() {
    let mut rng = Rng::new(13);
    let op = QuadraticOperator::random(8, 0.5, &mut rng);
    let x0 = vec![0.0; 8];

    let mut src_a = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.1 }, 16);
    let legacy =
        legacy_adam(&mut src_a, quant_boxes(8, 6, 2, 30), 0.05, true, &x0, 150);

    let mut src_b = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.1 }, 16);
    let mut solver = OptimisticAdam::new(&mut src_b, quant_boxes(8, 6, 2, 30), 0.05);
    let report = RunDriver::new().run(&mut solver, &x0, 150);
    assert_bit_identical(&legacy, &report);
}

//! Golden parity for the overlapped-exchange refactor.
//!
//! `ExchangeMode::Synchronous` must be **bit- and clock-identical** to the
//! pre-overlap (PR 3) coordinator: this file replays the PR 3 charging
//! arithmetic verbatim (per-topology formulas + the flat collective's
//! sampled jitter stream) and pins both engines, all three topologies and
//! the driver's `NetClock` against it across seeds. `ExchangeMode::
//! Overlapped` is then pinned to its invariants: the charge itself is
//! mode-invariant, `comm_exposed_s <= comm_s` with equality at a zero
//! compute window, `comm_exposed_s + comm_hidden_s == comm_s`, and the
//! engines agree bit-for-bit on the depth-stale iterate trajectory.

use qoda::coding::protocol::ProtocolKind;
use qoda::comm::{Adaptation, Compressor};
use qoda::coordinator::parallel::{
    run_rounds_over, worker_codec_seed, worker_oracle_seed, SharedQuantState,
};
use qoda::coordinator::sim::ClusterSim;
use qoda::coordinator::topology::PHASE_SETUP_MS;
use qoda::coordinator::{ExchangeMode, ExchangePlan, TopologySpec};
use qoda::net::{Collective, JitterModel, NetworkModel};
use qoda::oda::{CompressionSpec, NetClock, OperatorSpec, RunSpec, SolverKind};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::{LevelSequence, QuantConfig};
use qoda::stats::rng::Rng;
use qoda::vi::noise::{NoiseModel, Oracle};
use qoda::vi::operator::QuadraticOperator;

const D: usize = 24;
const K: usize = 6;

fn shared_state() -> SharedQuantState {
    SharedQuantState {
        map: LayerMap::from_spec(&[("a", 16, "ff"), ("b", 8, "emb")]).bucketed(8),
        cfg: QuantConfig {
            sequences: vec![LevelSequence::bits(4), LevelSequence::bits(6)],
            q: 2.0,
        },
        protocol: ProtocolKind::Main,
        adaptation: Adaptation::Fixed,
    }
}

fn topologies() -> [TopologySpec; 3] {
    [
        TopologySpec::BroadcastAllGather,
        TopologySpec::Hierarchical { racks: 3 },
        TopologySpec::ParameterServer,
    ]
}

// ---------------------------------------------------------------------------
// The PR 3 charging arithmetic, replayed verbatim. Any drift between these
// replicas and the live transports is a golden-parity break.
// ---------------------------------------------------------------------------

/// PR 3 `rack_spans`: contiguous blocks of ceil(k / racks). (The live
/// function now also clamps degenerate inputs; for the resolved racks >= 1
/// used here the layouts are identical.)
fn legacy_rack_spans(k: usize, racks: usize) -> Vec<(usize, usize)> {
    let racks = racks.clamp(1, k.max(1));
    let m = (k + racks - 1) / racks;
    let mut spans = Vec::new();
    let mut start = 0;
    while start < k {
        let end = (start + m).min(k);
        spans.push((start, end));
        start = end;
    }
    spans
}

/// PR 3 charge arithmetic for one exchange under `spec`, term for term.
fn legacy_charge(
    spec: &TopologySpec,
    packet_bits: &[u64],
    agg_dim: usize,
    net: &NetworkModel,
    uncompressed: bool,
    main_protocol: bool,
    rng: &mut Rng,
) -> (u64, f64) {
    match *spec {
        TopologySpec::BroadcastAllGather => {
            let bytes: Vec<f64> = packet_bits.iter().map(|&b| b as f64 / 8.0).collect();
            let kind = if uncompressed {
                Collective::RingAllReduce
            } else {
                Collective::RingAllGather
            };
            let comm_s = net.sample_collective_seconds(kind, &bytes, main_protocol, rng);
            (packet_bits.iter().sum(), comm_s)
        }
        TopologySpec::Hierarchical { racks } => {
            let k = packet_bits.len();
            let racks = if racks == 0 { (k / 4).max(2) } else { racks };
            let spans = legacy_rack_spans(k, racks);
            let r_eff = spans.len() as f64;
            let total_bits: u64 = packet_bits.iter().sum();
            let agg_bits = 32u64 * agg_dim as u64;

            let mut wire_bits = 0u64;
            let mut t_up = 0.0f64;
            for &(start, end) in &spans {
                let up_bits: u64 = packet_bits[start + 1..end].iter().sum();
                wire_bits += up_bits;
                if end - start > 1 {
                    let slow = net.max_slowdown_over(start..end);
                    let t = up_bits as f64 / 8.0 / net.intra_bytes_per_sec() * slow
                        + net.intra_rack_latency_us * 1e-6;
                    t_up = t_up.max(t);
                }
            }

            let leaders: Vec<usize> = spans.iter().map(|&(s, _)| s).collect();
            let slow_x = net.max_slowdown_over(leaders.iter().copied());
            let lat = net.latency_us * 1e-6;
            let bw = net.bytes_per_sec();
            let t_cross;
            if uncompressed {
                let a_bytes = agg_bits as f64 / 8.0;
                wire_bits += spans.len() as u64 * agg_bits;
                let wire = 2.0 * (r_eff - 1.0) / r_eff * a_bytes / bw
                    + 2.0 * (r_eff - 1.0) * lat;
                let straggler = net.straggler_ms_per_node_mb * 1e-3 * (a_bytes / 1e6)
                    * (r_eff - 1.0);
                t_cross = wire * slow_x + straggler;
            } else {
                let bundles: Vec<f64> = spans
                    .iter()
                    .map(|&(s, e)| packet_bits[s..e].iter().sum::<u64>() as f64 / 8.0)
                    .collect();
                wire_bits += total_bits;
                let sum_b: f64 = bundles.iter().sum();
                let max_b = bundles.iter().copied().fold(0.0, f64::max);
                let wire = (r_eff - 1.0) / r_eff * sum_b / bw + (r_eff - 1.0) * lat;
                let straggler =
                    net.straggler_ms_per_node_mb * 1e-3 * (max_b / 1e6) * (r_eff - 1.0);
                t_cross =
                    (wire * slow_x + straggler) * net.jitter_multiplier(main_protocol);
            }

            let mut t_down = 0.0f64;
            for &(start, end) in &spans {
                if end - start > 1 {
                    let down_bits = if uncompressed { agg_bits } else { total_bits };
                    wire_bits += down_bits;
                    let slow = net.max_slowdown_over(start..end);
                    let t = down_bits as f64 / 8.0 / net.intra_bytes_per_sec() * slow
                        + net.intra_rack_latency_us * 1e-6;
                    t_down = t_down.max(t);
                }
            }

            let comm_s = t_up + t_cross + t_down + 3.0 * PHASE_SETUP_MS * 1e-3;
            (wire_bits, comm_s)
        }
        TopologySpec::ParameterServer => {
            let k = packet_bits.len();
            let kf = k as f64;
            let total_bits: u64 = packet_bits.iter().sum();
            let agg_bits = 32u64 * agg_dim as u64;
            let bw = net.bytes_per_sec();
            let lat = net.latency_us * 1e-6;
            let slow = net.max_slowdown_over(0..k);
            let max_b =
                packet_bits.iter().map(|&b| b as f64 / 8.0).fold(0.0, f64::max);

            let up_wire = total_bits as f64 / 8.0 / bw * slow + lat;
            let up_straggler = net.straggler_ms_per_node_mb * 1e-3 * (max_b / 1e6)
                * (kf - 1.0).max(0.0);
            let t_up = (up_wire + up_straggler) * net.jitter_multiplier(main_protocol);

            let t_down = kf * (agg_bits as f64 / 8.0) / bw * slow + lat;

            let comm_s = t_up + t_down + 2.0 * PHASE_SETUP_MS * 1e-3;
            (total_bits + k as u64 * agg_bits, comm_s)
        }
    }
}

/// Randomized packet-bit vectors, deterministic per seed.
fn random_bits(rng: &mut Rng, k: usize) -> Vec<u64> {
    (0..k).map(|_| 256 + rng.below(1 << 14)).collect()
}

// ---------------------------------------------------------------------------
// 1. Transport charges: synchronous == PR 3, term for term, stream for
//    stream (jitter on, so the flat collective's RNG draws are exercised).
// ---------------------------------------------------------------------------

#[test]
fn synchronous_charges_match_pr3_bit_for_bit() {
    let mut jittered = NetworkModel::genesis_cloud(5.0).with_straggler(2, 2.5);
    jittered.jitter = JitterModel { p: 0.2, retrans_fraction: 1.0, resync_fraction: 0.05 };
    let d = 1 << 12;
    for seed in [3u64, 41, 97] {
        for spec in topologies() {
            for uncompressed in [false, true] {
                // one transport, one legacy replay, SAME rng seed: five
                // consecutive charges must agree on every float, which also
                // pins the sampled jitter stream position
                let mut transport = spec.build();
                let mut rng_live = Rng::new(seed);
                let mut rng_legacy = Rng::new(seed);
                let mut bits_rng = Rng::new(seed ^ 0xB17);
                for step in 0..5 {
                    let bits = random_bits(&mut bits_rng, K);
                    let live =
                        transport.charge(&bits, d, &jittered, uncompressed, true, &mut rng_live);
                    let (want_bits, want_s) = legacy_charge(
                        &spec,
                        &bits,
                        d,
                        &jittered,
                        uncompressed,
                        true,
                        &mut rng_legacy,
                    );
                    assert_eq!(
                        live.wire_bits, want_bits,
                        "wire bits drift ({spec:?}, seed {seed}, step {step})"
                    );
                    assert_eq!(
                        live.comm_s, want_s,
                        "network-clock drift ({spec:?}, seed {seed}, step {step}, \
                         uncompressed {uncompressed})"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Both engines, all topologies, three seeds: synchronous mode reproduces
//    PR 3's aggregates, wire bits and network-clock seconds bit-for-bit.
// ---------------------------------------------------------------------------

#[test]
fn engines_reproduce_pr3_accounting_across_topologies_and_seeds() {
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let mut op_rng = Rng::new(77);
    let op = QuadraticOperator::random(D, 0.5, &mut op_rng);
    let lr = 0.06;
    let steps = 3;
    let net = NetworkModel::genesis_cloud(5.0);

    for seed in [5u64, 17, 23] {
        for spec in topologies() {
            let st = shared_state();
            let x0 = vec![0.25; D];

            // engine 1: the threaded coordinator under the synchronous plan
            let par = run_rounds_over(
                &op,
                noise,
                K,
                &st,
                x0.clone(),
                steps,
                seed,
                &spec,
                &net,
                ExchangePlan::synchronous(),
                |x, mean, _| {
                    for (xi, g) in x.iter_mut().zip(mean) {
                        *xi -= lr * g;
                    }
                },
            )
            .expect("run_rounds_over");

            // engine 2: the sim engine, same per-node codec + oracle seeds,
            // with the PR 3 charge replayed alongside every round
            let codecs: Vec<Box<dyn Compressor>> = (0..K)
                .map(|n| Box::new(st.codec(worker_codec_seed(seed, n))) as _)
                .collect();
            let mut sim =
                ClusterSim::new(codecs, net.clone(), false).with_topology(&spec);
            let mut oracles: Vec<Oracle> = (0..K)
                .map(|n| Oracle::new(&op, noise, worker_oracle_seed(seed, n)))
                .collect();
            let mut x = x0;
            let mut wire_sim = 0u64;
            let mut comm_sim = 0.0f64;
            let mut wire_legacy = 0u64;
            let mut comm_legacy = 0.0f64;
            let mut legacy_rng = Rng::new(0xC0FFEE); // the sim engine's seed
            let mut last_mean = vec![0.0; D];
            for _ in 0..steps {
                let duals: Vec<Vec<f64>> =
                    oracles.iter_mut().map(|o| o.sample(&x)).collect();
                let (mean, m) = sim.exchange(&duals).expect("exchange");
                // replay PR 3 on the actual per-node packet sizes
                let bits: Vec<u64> = sim
                    .endpoints()
                    .iter()
                    .map(|e| e.packet().len_bits() as u64)
                    .collect();
                let (lb, ls) =
                    legacy_charge(&spec, &bits, D, &net, false, true, &mut legacy_rng);
                wire_legacy += lb;
                comm_legacy += ls;
                wire_sim += m.wire_bits;
                comm_sim += m.comm_s;
                // synchronous split: everything exposed, nothing hidden
                assert_eq!(m.comm_exposed_s, m.comm_s);
                assert_eq!(m.comm_hidden_s, 0.0);
                for (xi, g) in x.iter_mut().zip(&mean) {
                    *xi -= lr * g;
                }
                last_mean = mean;
            }

            // sim == PR 3 replay
            assert_eq!(wire_sim, wire_legacy, "({spec:?}, seed {seed})");
            assert_eq!(comm_sim, comm_legacy, "({spec:?}, seed {seed})");
            // threaded engine == sim engine, on everything
            assert_eq!(par.x, x, "iterate drift ({spec:?}, seed {seed})");
            assert_eq!(par.last_mean, last_mean, "aggregate drift ({spec:?})");
            assert_eq!(par.wire_bits, wire_sim, "wire drift ({spec:?})");
            assert_eq!(par.comm_s, comm_sim, "clock drift ({spec:?})");
            assert_eq!(par.comm_exposed_s, par.comm_s);
            assert_eq!(par.comm_hidden_s, 0.0);
        }
    }
}

// ---------------------------------------------------------------------------
// 3. The driver's NetClock: same charges off the same RNG stream as PR 3,
//    with or without an overlapped plan attached.
// ---------------------------------------------------------------------------

#[test]
fn netclock_sample_stream_matches_pr3_bit_for_bit() {
    let mut net = NetworkModel::genesis_cloud(5.0);
    net.jitter = JitterModel { p: 0.25, retrans_fraction: 1.0, resync_fraction: 0.05 };
    let k = 4usize;
    let d = 512usize;
    let totals = [40_000u64, 41_337, 39_991, 65_536, 12_345];

    let run_clock = |plan: Option<ExchangePlan>| -> Vec<(u64, f64)> {
        let mut clock = NetClock::new(
            &TopologySpec::BroadcastAllGather,
            net.clone(),
            false,
            true,
        );
        if let Some(p) = plan {
            clock = clock.with_exchange(p);
        }
        totals
            .iter()
            .map(|&t| {
                let c = clock.charge_step(t, k, d);
                (c.wire_bits, c.comm_s)
            })
            .collect()
    };

    // legacy replay: PR 3's equal split + sample stream from Rng(0x1C0C)
    let mut legacy_rng = Rng::new(0x1C0C);
    let want: Vec<(u64, f64)> = totals
        .iter()
        .map(|&total| {
            let base = total / k as u64;
            let rem = (total % k as u64) as usize;
            let mut bits = vec![base; k];
            for b in bits.iter_mut().take(rem) {
                *b += 1;
            }
            let bytes: Vec<f64> = bits.iter().map(|&b| b as f64 / 8.0).collect();
            let s = net.sample_collective_seconds(
                Collective::RingAllGather,
                &bytes,
                true,
                &mut legacy_rng,
            );
            (bits.iter().sum(), s)
        })
        .collect();

    assert_eq!(run_clock(None), want, "synchronous NetClock drifted from PR 3");
    // attaching an overlapped plan must not perturb the charge stream —
    // the split is accounting on top, never a different draw
    assert_eq!(
        run_clock(Some(ExchangePlan::overlapped(1, 0.050))),
        want,
        "overlapped NetClock perturbed the sample stream"
    );
}

// ---------------------------------------------------------------------------
// 4. Overlap invariants through the driver, every topology in the sweep:
//    exposed <= comm_s, equality at a zero compute window, the split
//    conserves comm_s, and overlap never worsens exposure vs synchronous.
// ---------------------------------------------------------------------------

#[test]
fn overlapped_exposure_invariants_across_the_topology_sweep() {
    for spec in topologies() {
        let run = |mode: ExchangeMode, compute_s: f64| {
            RunSpec::new(
                SolverKind::Qoda,
                OperatorSpec::Quadratic { dim: 16, mu: 0.5, seed: 11 },
            )
            .nodes(4)
            .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
            .steps(25)
            .seed(3)
            .topology(spec)
            .network(NetworkModel::genesis_cloud(5.0))
            .exchange(mode)
            .compute_per_step(compute_s)
            .run()
        };
        let sync = run(ExchangeMode::Synchronous, 0.0);
        assert!(sync.comm_s > 0.0, "{spec:?}");
        assert_eq!(sync.comm_exposed_s, sync.comm_s, "{spec:?}");
        assert_eq!(sync.comm_hidden_s, 0.0, "{spec:?}");

        // compute-per-step = 0: overlap exposes everything, exactly
        let ov0 = run(ExchangeMode::Overlapped { depth: 1 }, 0.0);
        assert_eq!(ov0.comm_s, sync.comm_s, "charge is mode-invariant ({spec:?})");
        assert_eq!(ov0.comm_exposed_s, ov0.comm_s, "{spec:?}");

        for compute_s in [1e-4, 1e-3, 5e-3, 1.0] {
            for depth in [1usize, 2] {
                let ov = run(ExchangeMode::Overlapped { depth }, compute_s);
                assert_eq!(ov.comm_s, sync.comm_s, "{spec:?}");
                assert_eq!(ov.x_last, sync.x_last, "clock must not touch math");
                // the acceptance invariant: overlap never increases the
                // exposed share over synchronous
                assert!(
                    ov.comm_exposed_s <= sync.comm_exposed_s,
                    "{spec:?} compute {compute_s} depth {depth}"
                );
                assert!(ov.comm_exposed_s >= 0.0 && ov.comm_hidden_s >= 0.0);
                assert!(
                    (ov.comm_exposed_s + ov.comm_hidden_s - ov.comm_s).abs()
                        <= 1e-12 * ov.comm_s,
                    "{spec:?}: split must conserve comm_s"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 5. Overlapped engines agree bit-for-bit on the depth-stale trajectory.
// ---------------------------------------------------------------------------

#[test]
fn overlapped_engines_agree_bitwise_on_the_stale_trajectory() {
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let mut op_rng = Rng::new(88);
    let op = QuadraticOperator::random(D, 0.5, &mut op_rng);
    let lr = 0.05;
    let steps = 5;
    let net = NetworkModel::genesis_cloud(5.0);

    for seed in [9u64, 31] {
        for depth in [1usize, 2] {
            for spec in [
                TopologySpec::BroadcastAllGather,
                TopologySpec::Hierarchical { racks: 3 },
            ] {
                let st = shared_state();
                let x0 = vec![0.2; D];
                let par = run_rounds_over(
                    &op,
                    noise,
                    K,
                    &st,
                    x0.clone(),
                    steps,
                    seed,
                    &spec,
                    &net,
                    ExchangePlan::overlapped(depth, 0.0),
                    |x, mean, _| {
                        for (xi, g) in x.iter_mut().zip(mean) {
                            *xi -= lr * g;
                        }
                    },
                )
                .expect("run_rounds_over");

                // sim engine replica of the same schedule: query the
                // current iterate, apply the (stale or zero) returned
                // aggregate, drain the double buffer at the end
                let codecs: Vec<Box<dyn Compressor>> = (0..K)
                    .map(|n| Box::new(st.codec(worker_codec_seed(seed, n))) as _)
                    .collect();
                let mut sim = ClusterSim::new(codecs, net.clone(), false)
                    .with_topology(&spec)
                    .with_exchange(ExchangePlan::overlapped(depth, 0.0));
                let mut oracles: Vec<Oracle> = (0..K)
                    .map(|n| Oracle::new(&op, noise, worker_oracle_seed(seed, n)))
                    .collect();
                let mut x = x0;
                let mut wire_sim = 0u64;
                let mut last_mean = vec![0.0; D];
                for _ in 0..steps {
                    let duals: Vec<Vec<f64>> =
                        oracles.iter_mut().map(|o| o.sample(&x)).collect();
                    let (stale, m) = sim.exchange(&duals).expect("exchange");
                    wire_sim += m.wire_bits;
                    for (xi, g) in x.iter_mut().zip(&stale) {
                        *xi -= lr * g;
                    }
                    last_mean = stale;
                }
                for mean in sim.drain_staged() {
                    for (xi, g) in x.iter_mut().zip(&mean) {
                        *xi -= lr * g;
                    }
                    last_mean = mean;
                }

                assert_eq!(
                    par.x, x,
                    "stale-iterate drift ({spec:?}, seed {seed}, depth {depth})"
                );
                assert_eq!(par.wire_bits, wire_sim, "({spec:?}, depth {depth})");
                // the final aggregate both engines saw last is the final
                // round's mean
                assert_eq!(par.last_mean, last_mean, "({spec:?}, depth {depth})");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 6. Staleness changes the trajectory (it must — otherwise nothing
//    overlapped) but a one-round run, drained, is exactly synchronous.
// ---------------------------------------------------------------------------

#[test]
fn staleness_is_real_but_degenerates_to_sync_on_one_round() {
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let mut op_rng = Rng::new(55);
    let op = QuadraticOperator::random(D, 0.5, &mut op_rng);
    let lr = 0.08;
    let st = shared_state();
    let x0 = vec![0.4; D];
    let net = NetworkModel::genesis_cloud(5.0);
    let run = |steps: usize, plan: ExchangePlan| {
        run_rounds_over(
            &op,
            noise,
            K,
            &st,
            x0.clone(),
            steps,
            13,
            &TopologySpec::BroadcastAllGather,
            &net,
            plan,
            |x, mean, _| {
                for (xi, g) in x.iter_mut().zip(mean) {
                    *xi -= lr * g;
                }
            },
        )
        .expect("run_rounds_over")
    };
    // multi-round: the stale trajectory genuinely differs...
    let sync = run(4, ExchangePlan::synchronous());
    let over = run(4, ExchangePlan::overlapped(1, 0.0));
    assert_ne!(sync.x, over.x, "overlap must actually stagger the updates");
    // ...but one round has nothing to stagger
    let sync1 = run(1, ExchangePlan::synchronous());
    let over1 = run(1, ExchangePlan::overlapped(1, 0.0));
    assert_eq!(sync1.x, over1.x);
    assert_eq!(sync1.last_mean, over1.last_mean);
    assert_eq!(sync1.comm_s, over1.comm_s);
}

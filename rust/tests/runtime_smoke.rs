//! Integration: the model runtime end-to-end — load both native models,
//! execute, check shapes/numerics, and cross-validate the rust quantizer
//! against the shared python testvectors when the artifacts are present
//! (`make artifacts` emits them; the offline image ships without).

use qoda::quant::layer_map::LayerMap;
use qoda::quant::LevelSequence;
use qoda::runtime::{LmModel, Runtime, WganModel};
use qoda::stats::rng::Rng;

fn runtime() -> Runtime {
    Runtime::cpu().expect("CPU runtime")
}

#[test]
fn wgan_model_loads_and_runs() {
    let rt = runtime();
    let model = WganModel::load(&rt).expect("load wgan model");
    assert!(model.dim > 1000);
    let params = model.init_params(0).unwrap();
    assert_eq!(params.len(), model.dim);
    assert!(params.iter().all(|x| x.is_finite()));

    let (dual, g_loss, w_dist) = model.dual(&params, 1).unwrap();
    assert_eq!(dual.len(), model.dim);
    assert!(dual.iter().all(|x| x.is_finite()));
    assert!(g_loss.is_finite() && w_dist.is_finite());

    // determinism: same seed, same dual
    let (dual2, _, _) = model.dual(&params, 1).unwrap();
    assert_eq!(dual, dual2);
    // different seed, different minibatch
    let (dual3, _, _) = model.dual(&params, 2).unwrap();
    assert_ne!(dual, dual3);

    let (fake, real) = model.samples(&params, 3).unwrap();
    assert_eq!(fake.len(), model.sample_n * 2);
    assert_eq!(real.len(), model.sample_n * 2);
    // real data lives near the radius-2 mode circle
    for chunk in real.chunks(2) {
        let r = (chunk[0] * chunk[0] + chunk[1] * chunk[1]).sqrt();
        assert!((r - 2.0).abs() < 0.5, "real point off-circle: {chunk:?}");
    }
}

#[test]
fn lm_model_loads_and_runs() {
    let rt = runtime();
    let model = LmModel::load(&rt).expect("load lm model");
    let params = model.init_params(0).unwrap();
    assert_eq!(params.len(), model.dim);

    let mut rng = Rng::new(7);
    let tokens: Vec<i32> = (0..model.batch * (model.seq + 1))
        .map(|_| rng.below(model.vocab as u64) as i32)
        .collect();
    let (grads, loss) = model.grad(&params, &tokens).unwrap();
    assert_eq!(grads.len(), model.dim);
    assert!(loss.is_finite());
    // at random init, loss ~ log(vocab)
    assert!((loss - (model.vocab as f32).ln()).abs() < 1.0, "loss {loss}");

    // one SGD step on the same batch reduces the loss
    let stepped: Vec<f32> =
        params.iter().zip(&grads).map(|(p, g)| p - 0.5 * g).collect();
    let loss2 = model.eval(&stepped, &tokens).unwrap();
    assert!(loss2 < loss, "{loss2} vs {loss}");

    // layer map types cover the figure-5 ablation categories
    let types: std::collections::BTreeSet<_> =
        model.meta.type_names.iter().cloned().collect();
    for t in ["embedding", "attention", "ff", "norm", "bias"] {
        assert!(types.contains(t), "missing type {t}");
    }
}

#[test]
fn python_testvectors_match_rust_quantizer() {
    // Shared vectors emitted by aot.py (kernel == ref asserted python-side);
    // here: rust bracket/rounding reproduces the ref outputs exactly.
    // Skipped (not failed) when the artifacts were never generated — the
    // offline image has no jax to produce them.
    let path = qoda::util::repo_path("artifacts/testvectors/quant_cases.txt");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("skipping: {} not present (run `make artifacts`)", path.display());
        return;
    };
    let mut lines = text.lines();
    let ncases: usize = lines
        .next()
        .unwrap()
        .strip_prefix("ncases ")
        .unwrap()
        .parse()
        .unwrap();
    let parse_vec = |line: &str, tag: &str| -> Vec<f32> {
        let rest = line.strip_prefix(tag).unwrap_or_else(|| panic!("want {tag}"));
        rest.split_whitespace().map(|t| t.parse::<f32>().unwrap()).collect()
    };
    for _ in 0..ncases {
        let hdr = lines.next().unwrap();
        let toks: Vec<&str> = hdr.split_whitespace().collect();
        assert_eq!(toks[0], "case");
        let q: f64 = toks[7].parse().unwrap();
        let v = parse_vec(lines.next().unwrap(), "v ");
        let levels = parse_vec(lines.next().unwrap(), "levels ");
        let u = parse_vec(lines.next().unwrap(), "u ");
        let expected = parse_vec(lines.next().unwrap(), "expected ");

        let seq = LevelSequence::new(levels.iter().map(|&x| x as f64).collect());
        let norm = qoda::stats::vecops::lq_norm(&v, q) as f32 as f64;
        let ls = seq.as_slice();
        for i in 0..v.len() {
            let mag = if norm > 0.0 {
                ((v[i].abs() as f64) / norm).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let tau = seq.bracket(mag);
            let xi = (mag - ls[tau]) / (ls[tau + 1] - ls[tau]).max(1e-38);
            let level = if (u[i] as f64) < xi { ls[tau + 1] } else { ls[tau] };
            let got = (norm * level) as f32 * if v[i] < 0.0 { -1.0 } else { 1.0 };
            assert!(
                (got - expected[i]).abs() <= 2e-5 * norm as f32,
                "case coord {i}: got {got} want {}",
                expected[i]
            );
        }
    }
}

#[test]
fn model_layer_maps_are_valid_and_heterogeneous() {
    let rt = runtime();
    let maps: Vec<(&str, LayerMap)> = vec![
        ("wgan", WganModel::load(&rt).unwrap().meta),
        ("lm", LmModel::load(&rt).unwrap().meta),
    ];
    for (name, m) in maps {
        m.validate().unwrap();
        assert!(m.num_types() >= 2, "{name} should be heterogeneous");
        // shapes fill the dim
        for l in &m.layers {
            assert_eq!(l.rows * l.cols, l.len, "{}", l.name);
        }
    }
}

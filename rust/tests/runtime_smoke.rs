//! Integration: the AOT bridge end-to-end — load every artifact, execute,
//! check shapes/numerics, and cross-validate the rust quantizer against the
//! L1 Pallas kernel running under PJRT.

use qoda::quant::layer_map::LayerMap;
use qoda::quant::LevelSequence;
use qoda::runtime::{pjrt, LmModel, Runtime, WganModel};
use qoda::stats::rng::Rng;

fn runtime() -> Runtime {
    Runtime::cpu().expect("PJRT CPU client")
}

#[test]
fn wgan_artifacts_load_and_run() {
    let rt = runtime();
    let model = WganModel::load(&rt).expect("load wgan artifacts");
    assert!(model.dim > 1000);
    let params = model.init_params(0).unwrap();
    assert_eq!(params.len(), model.dim);
    assert!(params.iter().all(|x| x.is_finite()));

    let (dual, g_loss, w_dist) = model.dual(&params, 1).unwrap();
    assert_eq!(dual.len(), model.dim);
    assert!(dual.iter().all(|x| x.is_finite()));
    assert!(g_loss.is_finite() && w_dist.is_finite());

    // determinism: same seed, same dual
    let (dual2, _, _) = model.dual(&params, 1).unwrap();
    assert_eq!(dual, dual2);
    // different seed, different minibatch
    let (dual3, _, _) = model.dual(&params, 2).unwrap();
    assert_ne!(dual, dual3);

    let (fake, real) = model.samples(&params, 3).unwrap();
    assert_eq!(fake.len(), model.sample_n * 2);
    assert_eq!(real.len(), model.sample_n * 2);
    // real data lives near the radius-2 mode circle
    for chunk in real.chunks(2) {
        let r = (chunk[0] * chunk[0] + chunk[1] * chunk[1]).sqrt();
        assert!((r - 2.0).abs() < 0.5, "real point off-circle: {chunk:?}");
    }
}

#[test]
fn lm_artifacts_load_and_run() {
    let rt = runtime();
    let model = LmModel::load(&rt).expect("load lm artifacts");
    let params = model.init_params(0).unwrap();
    assert_eq!(params.len(), model.dim);

    let mut rng = Rng::new(7);
    let tokens: Vec<i32> = (0..model.batch * (model.seq + 1))
        .map(|_| rng.below(model.vocab as u64) as i32)
        .collect();
    let (grads, loss) = model.grad(&params, &tokens).unwrap();
    assert_eq!(grads.len(), model.dim);
    assert!(loss.is_finite());
    // at random init, loss ~ log(vocab)
    assert!((loss - (model.vocab as f32).ln()).abs() < 1.0, "loss {loss}");

    // one SGD step on the same batch reduces the loss
    let stepped: Vec<f32> =
        params.iter().zip(&grads).map(|(p, g)| p - 0.5 * g).collect();
    let loss2 = model.eval(&stepped, &tokens).unwrap();
    assert!(loss2 < loss, "{loss2} vs {loss}");

    // layer map types cover the figure-5 ablation categories
    let types: std::collections::BTreeSet<_> =
        model.meta.type_names.iter().cloned().collect();
    for t in ["embedding", "attention", "ff", "norm", "bias"] {
        assert!(types.contains(t), "missing type {t}");
    }
}

#[test]
fn pallas_quantize_kernel_matches_rust_quantizer() {
    // The standalone L1 kernel artifact quantizes f32[4096] against an
    // 8-level table with explicit uniforms; the rust quantizer must agree
    // bit-for-bit when driven with the same uniforms.
    let rt = runtime();
    let exe = rt
        .load_artifact("artifacts/quantize_k8.hlo.txt")
        .expect("load quantize kernel");
    let n = 4096;
    let mut rng = Rng::new(42);
    let v: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let levels_f32: Vec<f32> = vec![0.0, 0.05, 0.12, 0.25, 0.45, 0.7, 0.88, 1.0];
    let uniforms: Vec<f32> = (0..n).map(|_| rng.uniform_f32()).collect();

    let out = exe
        .run(&[pjrt::lit_f32(&v), pjrt::lit_f32(&levels_f32), pjrt::lit_f32(&uniforms)])
        .unwrap();
    let kernel_out = pjrt::to_f32(&out[0]).unwrap();

    // rust-side quantization with the same uniforms (norm rounded to f32 to
    // match the wire convention; the kernel normalizes by the f64->f32 norm)
    let seq = LevelSequence::new(levels_f32.iter().map(|&x| x as f64).collect());
    let norm = qoda::stats::vecops::lq_norm(&v, 2.0);
    let ls = seq.as_slice();
    let mut rust_out = vec![0.0f32; n];
    for i in 0..n {
        let mag = ((v[i].abs() as f64) / norm).clamp(0.0, 1.0);
        let tau = seq.bracket(mag);
        let xi = (mag - ls[tau]) / (ls[tau + 1] - ls[tau]).max(1e-38);
        let pick_hi = (uniforms[i] as f64) < xi;
        let level = if pick_hi { ls[tau + 1] } else { ls[tau] };
        rust_out[i] = (norm * level) as f32 * v[i].signum();
    }
    let mut mismatches = 0;
    for i in 0..n {
        if (kernel_out[i] - rust_out[i]).abs() > 1e-4 * norm as f32 {
            mismatches += 1;
        }
    }
    // tiny tolerance for f32-vs-f64 normalization boundary flips
    assert!(mismatches <= n / 500, "{mismatches} mismatches of {n}");
}

#[test]
fn python_testvectors_match_rust_quantizer() {
    // Shared vectors emitted by aot.py (kernel == ref asserted python-side);
    // here: rust bracket/rounding reproduces the ref outputs exactly.
    let path = qoda::util::repo_path("artifacts/testvectors/quant_cases.txt");
    let text = std::fs::read_to_string(&path).expect("testvectors (run make artifacts)");
    let mut lines = text.lines();
    let ncases: usize = lines
        .next()
        .unwrap()
        .strip_prefix("ncases ")
        .unwrap()
        .parse()
        .unwrap();
    let parse_vec = |line: &str, tag: &str| -> Vec<f32> {
        let rest = line.strip_prefix(tag).unwrap_or_else(|| panic!("want {tag}"));
        rest.split_whitespace().map(|t| t.parse::<f32>().unwrap()).collect()
    };
    for _ in 0..ncases {
        let hdr = lines.next().unwrap();
        let toks: Vec<&str> = hdr.split_whitespace().collect();
        assert_eq!(toks[0], "case");
        let q: f64 = toks[7].parse().unwrap();
        let v = parse_vec(lines.next().unwrap(), "v ");
        let levels = parse_vec(lines.next().unwrap(), "levels ");
        let u = parse_vec(lines.next().unwrap(), "u ");
        let expected = parse_vec(lines.next().unwrap(), "expected ");

        let seq = LevelSequence::new(levels.iter().map(|&x| x as f64).collect());
        let norm = qoda::stats::vecops::lq_norm(&v, q) as f32 as f64;
        let ls = seq.as_slice();
        for i in 0..v.len() {
            let mag = if norm > 0.0 {
                ((v[i].abs() as f64) / norm).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let tau = seq.bracket(mag);
            let xi = (mag - ls[tau]) / (ls[tau + 1] - ls[tau]).max(1e-38);
            let level = if (u[i] as f64) < xi { ls[tau + 1] } else { ls[tau] };
            let got = (norm * level) as f32 * if v[i] < 0.0 { -1.0 } else { 1.0 };
            assert!(
                (got - expected[i]).abs() <= 2e-5 * norm as f32,
                "case coord {i}: got {got} want {}",
                expected[i]
            );
        }
    }
}

#[test]
fn meta_layer_maps_are_valid() {
    for name in ["artifacts/wgan.meta", "artifacts/lm.meta"] {
        let m = LayerMap::load_meta(&qoda::util::repo_path(name)).unwrap();
        m.validate().unwrap();
        assert!(m.num_types() >= 2, "{name} should be heterogeneous");
        // shapes fill the dim
        for l in &m.layers {
            assert_eq!(l.rows * l.cols, l.len, "{}", l.name);
        }
    }
}

//! Parity for decode-count-scheduled adaptation (`Adaptation::Scheduled`):
//! the threaded coordinator and the deterministic sim engine, driven by the
//! same seeds through the same `comm` codecs, must stay **bit-identical**
//! while the schedule re-plans bit widths and retunes codebooks mid-run.
//!
//! The mechanism under test: every consumer of node n's stream (the node's
//! own self-decode, the threaded leader's per-node replica, the sim
//! engine's endpoint) folds identical receiver-side statistics at identical
//! decode counts, so all of them re-plan to identical books with no side
//! channel. One desynchronized update anywhere and the entropy decode
//! diverges immediately — equality of the decoded aggregates across engines
//! is therefore a sharp pin, not a smoke test.

use qoda::comm::{Adaptation, Compressor};
use qoda::coordinator::parallel::{
    run_rounds, worker_codec_seed, worker_oracle_seed, SharedQuantState,
};
use qoda::coordinator::sim::ClusterSim;
use qoda::net::NetworkModel;
use qoda::quant::layer_map::LayerMap;
use qoda::quant::QuantConfig;
use qoda::stats::rng::Rng;
use qoda::vi::noise::{NoiseModel, Oracle};
use qoda::vi::operator::QuadraticOperator;

const D: usize = 24;
const K: usize = 3;
const STEPS: usize = 6;
const LR: f64 = 0.07;

/// `every: 2` over 6 steps fires the re-plan at decode counts 2 and 4 (the
/// count-6 update would first apply to a 7th packet), so the run crosses
/// two live codebook updates.
fn scheduled_state() -> SharedQuantState {
    let map = LayerMap::from_spec(&[("a", 16, "ff"), ("b", 8, "emb")]).bucketed(8);
    let cfg = QuantConfig::uniform_bits(map.num_types(), 4, 2.0);
    SharedQuantState {
        map,
        cfg,
        protocol: qoda::coding::protocol::ProtocolKind::Main,
        adaptation: Adaptation::Scheduled {
            every: 2,
            budget_bits_per_coord: 5.0,
            max_bits: 6,
        },
    }
}

/// The sim-engine reference: per-node codecs and oracles built from the
/// exact worker seed formulas, each endpoint encoding and self-decoding its
/// own packet (one decode per round per codec — the same counter the
/// threaded worker and its leader replica advance).
fn sim_run(
    op: &QuadraticOperator,
    noise: NoiseModel,
    st: &SharedQuantState,
    x0: &[f64],
    seed: u64,
) -> (Vec<f64>, Vec<f64>) {
    let comps: Vec<Box<dyn Compressor>> = (0..K)
        .map(|n| {
            Box::new(st.codec(worker_codec_seed(seed, n))) as Box<dyn Compressor>
        })
        .collect();
    let mut sim = ClusterSim::new(comps, NetworkModel::genesis_cloud(5.0), false);
    let mut oracles: Vec<Oracle> = (0..K)
        .map(|n| Oracle::new(op, noise, worker_oracle_seed(seed, n)))
        .collect();
    let mut x = x0.to_vec();
    let mut last_mean = vec![0.0; D];
    for _t in 1..=STEPS {
        let duals: Vec<Vec<f64>> =
            oracles.iter_mut().map(|o| o.sample(&x)).collect();
        let (mean, _metrics) = sim.exchange(&duals).expect("sim exchange");
        for (xi, g) in x.iter_mut().zip(&mean) {
            *xi -= LR * g;
        }
        last_mean = mean;
    }
    (x, last_mean)
}

#[test]
fn scheduled_runs_are_bit_identical_across_engines_and_seeds() {
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let mut op_rng = Rng::new(99);
    let op = QuadraticOperator::random(D, 0.5, &mut op_rng);
    for seed in [11u64, 29, 47] {
        let st = scheduled_state();
        let x0 = vec![0.3; D];

        let (x_par, bits_par, mean_par) = run_rounds(
            &op,
            noise,
            K,
            &st,
            x0.clone(),
            STEPS,
            seed,
            |x, mean, _| {
                for (xi, g) in x.iter_mut().zip(mean) {
                    *xi -= LR * g;
                }
            },
        )
        .expect("threaded scheduled run");

        let (x_sim, mean_sim) = sim_run(&op, noise, &st, &x0, seed);

        assert_eq!(x_par, x_sim, "seed {seed}: iterates diverged");
        assert_eq!(mean_par, mean_sim, "seed {seed}: last aggregates diverged");
        assert!(bits_par > 0, "seed {seed}: no wire bits charged");
    }
}

#[test]
fn scheduled_run_actually_reallocates() {
    // the parity above would hold vacuously if the schedule never fired;
    // pin that the scheduled run's wire spend differs from the same run
    // with adaptation pinned off (identical cfg, seeds and oracle stream)
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let mut op_rng = Rng::new(99);
    let op = QuadraticOperator::random(D, 0.5, &mut op_rng);
    let x0 = vec![0.3; D];
    let run = |st: &SharedQuantState| {
        run_rounds(&op, noise, K, st, x0.clone(), STEPS, 11, |x, mean, _| {
            for (xi, g) in x.iter_mut().zip(mean) {
                *xi -= LR * g;
            }
        })
        .expect("run")
    };
    let scheduled = run(&scheduled_state());
    let mut fixed_st = scheduled_state();
    fixed_st.adaptation = Adaptation::Fixed;
    let fixed = run(&fixed_st);
    // first update fires at decode count 2 of 6: books were retuned against
    // measured statistics, so the entropy-coded wire totals must move
    assert_ne!(
        scheduled.1, fixed.1,
        "scheduled adaptation never changed the wire stream"
    );
}

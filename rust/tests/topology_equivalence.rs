//! Cross-topology equivalence: every topology, on both engines, across
//! seeds, must produce bit-identical aggregates and iterates — a topology
//! is a routing/charging plan, never math. Per-topology wire-bit totals
//! must match their analytic formulas, and the flat broadcast topology must
//! charge the exact pre-refactor network-clock time (golden parity).

use qoda::coding::protocol::ProtocolKind;
use qoda::comm::{Adaptation, Compressor};
use qoda::coordinator::collectives::{assign_layers_by_bits, split_share};
use qoda::coordinator::parallel::{
    run_rounds_over, worker_codec_seed, worker_oracle_seed, SharedQuantState,
};
use qoda::coordinator::sim::ClusterSim;
use qoda::coordinator::{ExchangePlan, TopologySpec};
use qoda::net::{Collective, NetworkModel};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::{LevelSequence, QuantConfig};
use qoda::stats::rng::Rng;
use qoda::vi::noise::{NoiseModel, Oracle};
use qoda::vi::operator::QuadraticOperator;

const D: usize = 24;
const K: usize = 6;

fn shared_state() -> SharedQuantState {
    SharedQuantState {
        map: LayerMap::from_spec(&[("a", 16, "ff"), ("b", 8, "emb")]).bucketed(8),
        cfg: QuantConfig {
            sequences: vec![LevelSequence::bits(4), LevelSequence::bits(6)],
            q: 2.0,
        },
        protocol: ProtocolKind::Main,
        adaptation: Adaptation::Fixed,
    }
}

fn topologies() -> [TopologySpec; 5] {
    [
        TopologySpec::BroadcastAllGather,
        TopologySpec::Hierarchical { racks: 3 },
        TopologySpec::ParameterServer,
        TopologySpec::ShardedReduceScatter,
        TopologySpec::Ring,
    ]
}

/// All topologies x both engines x 3 seeds: aggregates, iterates and (per
/// topology) wire-bit totals agree bit-for-bit.
#[test]
fn topologies_and_engines_agree_bitwise_across_seeds() {
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let mut op_rng = Rng::new(99);
    let op = QuadraticOperator::random(D, 0.5, &mut op_rng);
    let lr = 0.07;
    let steps = 4;
    let net = NetworkModel::genesis_cloud(5.0);

    for seed in [11u64, 29, 47] {
        let st = shared_state();
        let x0 = vec![0.3; D];
        // (x, last_mean, wire_bits) per (topology, engine)
        let mut reference: Option<(Vec<f64>, Vec<f64>)> = None;
        for spec in topologies() {
            // threaded engine
            let par = run_rounds_over(
                &op,
                noise,
                K,
                &st,
                x0.clone(),
                steps,
                seed,
                &spec,
                &net,
                ExchangePlan::synchronous(),
                |x, mean, _| {
                    for (xi, g) in x.iter_mut().zip(mean) {
                        *xi -= lr * g;
                    }
                },
            )
            .expect("run_rounds_over");

            // sim engine with the same per-node codec + oracle seeds
            let codecs: Vec<Box<dyn Compressor>> = (0..K)
                .map(|n| Box::new(st.codec(worker_codec_seed(seed, n))) as _)
                .collect();
            let mut sim =
                ClusterSim::new(codecs, net.clone(), false).with_topology(&spec);
            let mut oracles: Vec<Oracle> = (0..K)
                .map(|n| Oracle::new(&op, noise, worker_oracle_seed(seed, n)))
                .collect();
            let mut x = x0.clone();
            let mut bits_sim = 0u64;
            let mut last_mean = vec![0.0; D];
            for _ in 0..steps {
                let duals: Vec<Vec<f64>> =
                    oracles.iter_mut().map(|o| o.sample(&x)).collect();
                let (mean, m) = sim.exchange(&duals).expect("exchange");
                bits_sim += m.wire_bits;
                for (xi, g) in x.iter_mut().zip(&mean) {
                    *xi -= lr * g;
                }
                last_mean = mean;
            }

            // engines agree on everything, including the topology's charge
            assert_eq!(par.x, x, "iterate mismatch ({spec:?}, seed {seed})");
            assert_eq!(
                par.last_mean, last_mean,
                "aggregate mismatch ({spec:?}, seed {seed})"
            );
            assert_eq!(
                par.wire_bits, bits_sim,
                "wire bit mismatch ({spec:?}, seed {seed})"
            );
            assert!(par.comm_s > 0.0);

            // topologies agree on the math (aggregates/iterates), while the
            // wire accounting is allowed (expected) to differ
            match &reference {
                None => reference = Some((par.x.clone(), par.last_mean.clone())),
                Some((rx, rm)) => {
                    assert_eq!(&par.x, rx, "cross-topology iterate drift ({spec:?})");
                    assert_eq!(
                        &par.last_mean, rm,
                        "cross-topology aggregate drift ({spec:?})"
                    );
                }
            }
        }
    }
}

/// Per-topology wire-bit totals match the analytic formulas, with the real
/// (heterogeneous, entropy-coded) per-node packet sizes recovered from the
/// same seeded codecs the engines use.
#[test]
fn wire_bits_match_analytic_formulas() {
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let mut op_rng = Rng::new(7);
    let op = QuadraticOperator::random(D, 0.5, &mut op_rng);
    let st = shared_state();
    let seed = 31u64;
    let x0 = vec![0.25; D];
    let net = NetworkModel::genesis_cloud(5.0);

    // per-node packets of the single round, from fresh codecs seeded
    // exactly like the engines' workers
    let packets: Vec<_> = (0..K)
        .map(|n| {
            let mut oracle = Oracle::new(&op, noise, worker_oracle_seed(seed, n));
            let mut codec = st.codec(worker_codec_seed(seed, n));
            let dual = oracle.sample(&x0);
            codec.encode(&dual).expect("encode")
        })
        .collect();
    let b: Vec<u64> = packets.iter().map(|p| p.len_bits() as u64).collect();
    let total: u64 = b.iter().sum();
    let agg_bits = 32 * D as u64;

    // racks of 2: leaders are nodes 0, 2, 4; every rack has a member, so
    // each pays the full-packet-set multicast down
    let expected_hier: u64 = (b[1] + b[3] + b[5]) // up: non-leaders
        + total                                   // cross: bundles, once each
        + 3 * total; // down: full packet set per multi-member rack
    // sharded: ownership balances on the summed per-layer coded bits the
    // engines observe; node j keeps its own shard, ships the rest, and the
    // fp32 slice allgather crosses once -> W = sum_j (b_j - s_jj) + 32 d
    let tables: Vec<Vec<u64>> = packets.iter().map(|p| p.layer_bits()).collect();
    let sums: Vec<u64> = (0..tables[0].len())
        .map(|l| tables.iter().map(|t| t[l]).sum())
        .collect();
    let ranges = assign_layers_by_bits(&sums, K);
    let own_total: u64 = tables
        .iter()
        .enumerate()
        .map(|(j, t)| t[ranges[j].0..ranges[j].1].iter().sum::<u64>())
        .sum();
    let expected_sharded = total - own_total + agg_bits;
    // ring: K fixed chunk slots sized by the worst packet's share, each
    // crossing 2 (K-1) times -> W = 2 (K-1) sum_o max_j split(b_j, o, K)
    let chunk_sum: u64 = (0..K)
        .map(|o| b.iter().map(|&bits| split_share(bits, o, K)).max().unwrap_or(0))
        .sum();
    let expected_ring = 2 * (K as u64 - 1) * chunk_sum;
    let expected = [
        (TopologySpec::BroadcastAllGather, total),
        (TopologySpec::Hierarchical { racks: 3 }, expected_hier),
        (TopologySpec::ParameterServer, total + K as u64 * agg_bits),
        (TopologySpec::ShardedReduceScatter, expected_sharded),
        (TopologySpec::Ring, expected_ring),
    ];

    for (spec, want) in expected {
        let report = run_rounds_over(
            &op,
            noise,
            K,
            &st,
            x0.clone(),
            1,
            seed,
            &spec,
            &net,
            ExchangePlan::synchronous(),
            |_, _, _| {},
        )
        .expect("run_rounds_over");
        assert_eq!(report.wire_bits, want, "wire formula mismatch ({spec:?})");
    }
    // the formulas are genuinely distinct on this workload
    assert!(expected_hier > total);
}

/// fp32 in-network reduction formulas: with identity compressors (b_i =
/// 32d) the hierarchical topology reduces rack-locally, so `W = (K + 2R -
/// #nonleader-corrected)`... concretely: up (K - R) + cross R + down R
/// aggregate-sized vectors.
#[test]
fn fp32_reduce_wire_formulas() {
    use qoda::comm::IdentityCompressor;
    let d = 16usize;
    let k = 6usize;
    let a = 32 * d as u64;
    let duals: Vec<Vec<f64>> = {
        let mut rng = Rng::new(3);
        (0..k).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect()
    };
    let mk = || -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor::new()) as _).collect()
    };
    let net = NetworkModel::genesis_cloud(5.0);

    let (_, flat) = ClusterSim::new(mk(), net.clone(), true).exchange(&duals).unwrap();
    assert_eq!(flat.wire_bits, k as u64 * a);

    let (_, hier) = ClusterSim::new(mk(), net.clone(), true)
        .with_topology(&TopologySpec::Hierarchical { racks: 3 })
        .exchange(&duals)
        .unwrap();
    // 3 racks of 2: up = 3 member grads, cross = 3 leader contributions,
    // down = 3 aggregate multicasts — all aggregate-sized
    assert_eq!(hier.wire_bits, 3 * a + 3 * a + 3 * a);

    let (_, ps) = ClusterSim::new(mk(), net.clone(), true)
        .with_topology(&TopologySpec::ParameterServer)
        .exchange(&duals)
        .unwrap();
    assert_eq!(ps.wire_bits, k as u64 * a + k as u64 * a);

    // identity packets carry one layer window, so sharding degenerates to a
    // single owner: 5 shipped packets plus one aggregate-sized allgather —
    // coincidentally exactly flat's total
    let (_, sharded) = ClusterSim::new(mk(), net.clone(), true)
        .with_topology(&TopologySpec::ShardedReduceScatter)
        .exchange(&duals)
        .unwrap();
    assert_eq!(sharded.wire_bits, k as u64 * a);

    // ring: K chunk slots summing to one packet, each crossing 2(K-1) links
    let (_, ring) = ClusterSim::new(mk(), net, true)
        .with_topology(&TopologySpec::Ring)
        .exchange(&duals)
        .unwrap();
    assert_eq!(ring.wire_bits, 2 * (k as u64 - 1) * a);
}

/// Golden parity of the network clock: the flat topology must charge the
/// byte-exact collective sample the pre-refactor engine drew, from the same
/// RNG stream.
#[test]
fn flat_network_clock_golden_parity() {
    let st = shared_state();
    let codecs: Vec<Box<dyn Compressor>> =
        (0..K).map(|n| Box::new(st.codec(worker_codec_seed(5, n))) as _).collect();
    let net = NetworkModel::genesis_cloud(5.0);
    let mut sim = ClusterSim::new(codecs, net.clone(), false);
    let duals: Vec<Vec<f64>> = {
        let mut rng = Rng::new(13);
        (0..K).map(|_| (0..D).map(|_| rng.gaussian()).collect()).collect()
    };
    let (_, m) = sim.exchange(&duals).unwrap();
    // replay the legacy charging path: per-node encoded bytes through
    // sample_collective_seconds with the engine's seed (0xC0FFEE)
    let bytes: Vec<f64> = sim
        .endpoints()
        .iter()
        .map(|e| e.packet().len_bits() as f64 / 8.0)
        .collect();
    let mut legacy_rng = Rng::new(0xC0FFEE);
    let want =
        net.sample_collective_seconds(Collective::RingAllGather, &bytes, true, &mut legacy_rng);
    assert_eq!(m.comm_s, want, "network-clock drift vs pre-refactor charging");
}

//! Integration: the measured-wire TCP engine vs the in-process engines.
//!
//! The acceptance bar mirrors `distributed_e2e`: driven by the same seeds
//! through the same `comm` codecs, a real-socket run must produce
//! bit-identical aggregates, identical final iterates and identical wire
//! bit counts — across both coding protocols, several seeds, flat,
//! hierarchical and sharded-mesh topologies, and both exchange schedules
//! (the mesh is synchronous-only and declines overlap with a typed error).
//! On top of that,
//! the wire-only guarantees: measured per-round records are internally
//! consistent, decoded duals are deterministic across reruns, and a worker
//! dying mid-round surfaces as `CommError::WorkerLost` promptly instead of
//! deadlocking the cluster.

use qoda::coding::protocol::ProtocolKind;
use qoda::comm::{Adaptation, CommError, Compressor, IdentityCompressor};
use qoda::coordinator::parallel::{
    run_rounds_over, worker_codec_seed, worker_oracle_seed, SharedQuantState,
};
use qoda::coordinator::sim::ClusterSim;
use qoda::coordinator::{ExchangePlan, TopologySpec};
use qoda::net::NetworkModel;
use qoda::quant::layer_map::LayerMap;
use qoda::quant::{LevelSequence, QuantConfig};
use qoda::stats::rng::Rng;
use qoda::vi::noise::{NoiseModel, Oracle};
use qoda::vi::operator::QuadraticOperator;
use qoda::wire::{run_wire, SocketConfig, WireCodecSpec, WireOptions, Workload};
use std::time::{Duration, Instant};

const D: usize = 24;
const K: usize = 3;
const STEPS: usize = 4;
const LR: f64 = 0.07;

fn descent(x: &mut Vec<f64>, mean: &[f64], _t: usize) {
    for (xi, g) in x.iter_mut().zip(mean) {
        *xi -= LR * g;
    }
}

fn test_op() -> QuadraticOperator {
    let mut rng = Rng::new(99);
    QuadraticOperator::random(D, 0.5, &mut rng)
}

fn quant_state(protocol: ProtocolKind) -> SharedQuantState {
    SharedQuantState {
        map: LayerMap::from_spec(&[("a", 16, "ff"), ("b", 8, "emb")]).bucketed(8),
        cfg: QuantConfig {
            sequences: vec![LevelSequence::bits(4), LevelSequence::bits(6)],
            q: 2.0,
        },
        protocol,
        adaptation: Adaptation::Fixed,
    }
}

/// The reference: the deterministic sim driven exactly like the wire
/// workers (shared per-node codec + oracle seed formulas, same update).
/// Returns (final x, total wire bits, mean decoded vector of last round).
fn sim_reference(
    op: &QuadraticOperator,
    noise: NoiseModel,
    k: usize,
    codecs: Vec<Box<dyn Compressor>>,
    x0: &[f64],
    steps: usize,
    seed: u64,
) -> (Vec<f64>, u64, Vec<f64>) {
    let mut sim = ClusterSim::new(codecs, NetworkModel::genesis_cloud(5.0), false);
    let mut oracles: Vec<Oracle> = (0..k)
        .map(|n| Oracle::new(op, noise, worker_oracle_seed(seed, n)))
        .collect();
    let mut x = x0.to_vec();
    let mut bits = 0u64;
    let mut last_mean = vec![0.0; x0.len()];
    for t in 1..=steps {
        let duals: Vec<Vec<f64>> = oracles.iter_mut().map(|o| o.sample(&x)).collect();
        let (mean, m) = sim.exchange(&duals).expect("sim exchange");
        bits += m.wire_bits;
        descent(&mut x, &mean, t);
        last_mean = mean;
    }
    (x, bits, last_mean)
}

/// The headline parity pin: a real-TCP run is bit-identical to `ClusterSim`
/// on the final iterate, the last aggregate AND the total wire bit count —
/// for both coding protocols and several seeds.
#[test]
fn wire_and_sim_agree_bitwise_across_protocols_and_seeds() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let x0 = vec![0.3; D];

    for protocol in [ProtocolKind::Main, ProtocolKind::Alternating] {
        for seed in [11u64, 29, 47] {
            let st = quant_state(protocol);
            let report = run_wire(
                Workload::Oracle { op: &op, noise },
                K,
                &WireCodecSpec::Quant(st.clone()),
                &x0,
                STEPS,
                seed,
                &TopologySpec::BroadcastAllGather,
                ExchangePlan::synchronous(),
                &WireOptions::default(),
                &descent,
            )
            .expect("wire run");

            let codecs: Vec<Box<dyn Compressor>> = (0..K)
                .map(|n| Box::new(st.codec(worker_codec_seed(seed, n))) as _)
                .collect();
            let (x_sim, bits_sim, mean_sim) =
                sim_reference(&op, noise, K, codecs, &x0, STEPS, seed);

            assert_eq!(
                report.last_mean, mean_sim,
                "aggregate mismatch ({protocol:?}, seed {seed})"
            );
            assert_eq!(report.x, x_sim, "iterate mismatch ({protocol:?}, seed {seed})");
            assert_eq!(
                report.payload_bits, bits_sim,
                "wire bit count mismatch ({protocol:?}, seed {seed})"
            );
            assert!(report.payload_bits > 0);
            assert_eq!(report.last_decoded.len(), K);
        }
    }
}

/// fp32 (identity codec) parity: the uncompressed baseline travels the same
/// frames and must agree with the sim's identity endpoints bit-for-bit.
#[test]
fn identity_wire_matches_sim_fp32() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let x0 = vec![0.3; D];
    let seed = 7u64;

    let report = run_wire(
        Workload::Oracle { op: &op, noise },
        K,
        &WireCodecSpec::Identity,
        &x0,
        STEPS,
        seed,
        &TopologySpec::BroadcastAllGather,
        ExchangePlan::synchronous(),
        &WireOptions::default(),
        &descent,
    )
    .expect("identity wire run");

    let codecs: Vec<Box<dyn Compressor>> = (0..K)
        .map(|_| Box::new(IdentityCompressor::new()) as _)
        .collect();
    let (x_sim, bits_sim, mean_sim) = sim_reference(&op, noise, K, codecs, &x0, STEPS, seed);

    assert_eq!(report.last_mean, mean_sim);
    assert_eq!(report.x, x_sim);
    assert_eq!(report.payload_bits, bits_sim);
}

/// Hierarchical routing is a physical plan, not a math change: the two-level
/// wire run (members -> rack leaders -> leader) must be bit-identical to the
/// flat wire run and the sim on every pinned quantity, including each node's
/// decoded dual of the last round.
#[test]
fn hierarchical_wire_is_bit_identical_to_flat() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let k = 6;
    let x0 = vec![0.3; D];
    let seed = 29u64;
    let st = quant_state(ProtocolKind::Main);

    let run = |topology: &TopologySpec| {
        run_wire(
            Workload::Oracle { op: &op, noise },
            k,
            &WireCodecSpec::Quant(st.clone()),
            &x0,
            STEPS,
            seed,
            topology,
            ExchangePlan::synchronous(),
            &WireOptions::default(),
            &descent,
        )
        .expect("wire run")
    };
    let flat = run(&TopologySpec::BroadcastAllGather);
    let hier = run(&TopologySpec::Hierarchical { racks: 2 });

    assert_eq!(hier.last_mean, flat.last_mean);
    assert_eq!(hier.x, flat.x);
    assert_eq!(hier.payload_bits, flat.payload_bits);
    assert_eq!(hier.last_decoded, flat.last_decoded);

    let codecs: Vec<Box<dyn Compressor>> = (0..k)
        .map(|n| Box::new(st.codec(worker_codec_seed(seed, n))) as _)
        .collect();
    let (x_sim, bits_sim, mean_sim) = sim_reference(&op, noise, k, codecs, &x0, STEPS, seed);
    assert_eq!(hier.last_mean, mean_sim);
    assert_eq!(hier.x, x_sim);
    assert_eq!(hier.payload_bits, bits_sim);
}

/// The sharded reduce-scatter over real sockets — a genuine peer-to-peer
/// mesh, not a star — must still be bit-identical to the flat wire run and
/// the sim on the aggregate, the iterate and the payload-bit ledger: owners
/// partial-decode only their slice, yet concatenated slice folds equal the
/// full fold exactly. `last_decoded` stays empty (no node ever holds all
/// K decoded duals), and the mesh reports a nonzero measured peak link.
#[test]
fn sharded_wire_is_bit_identical_to_flat() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let k = 6;
    let x0 = vec![0.3; D];
    let seed = 29u64;
    let st = quant_state(ProtocolKind::Main);

    let run = |topology: &TopologySpec| {
        run_wire(
            Workload::Oracle { op: &op, noise },
            k,
            &WireCodecSpec::Quant(st.clone()),
            &x0,
            STEPS,
            seed,
            topology,
            ExchangePlan::synchronous(),
            &WireOptions::default(),
            &descent,
        )
        .expect("wire run")
    };
    let flat = run(&TopologySpec::BroadcastAllGather);
    let sharded = run(&TopologySpec::ShardedReduceScatter);

    assert_eq!(sharded.last_mean, flat.last_mean);
    assert_eq!(sharded.x, flat.x);
    assert_eq!(sharded.payload_bits, flat.payload_bits);
    assert!(sharded.last_decoded.is_empty(), "no mesh node decodes all K duals");
    assert!(sharded.peak_link_bytes > 0.0);
    assert_eq!(sharded.rounds.len(), STEPS);
    for r in &sharded.rounds {
        assert!(r.peak_link_bytes > 0.0, "round {}", r.round);
    }

    let codecs: Vec<Box<dyn Compressor>> = (0..k)
        .map(|n| Box::new(st.codec(worker_codec_seed(seed, n))) as _)
        .collect();
    let (x_sim, bits_sim, mean_sim) = sim_reference(&op, noise, k, codecs, &x0, STEPS, seed);
    assert_eq!(sharded.last_mean, mean_sim);
    assert_eq!(sharded.x, x_sim);
    assert_eq!(sharded.payload_bits, bits_sim);
}

/// Identity payloads through the sharded mesh: one layer window means one
/// owner does the whole fold, the degenerate-but-legal corner of the
/// ownership assignment — parity must still hold.
#[test]
fn sharded_wire_identity_matches_sim() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let x0 = vec![0.3; D];
    let seed = 7u64;

    let report = run_wire(
        Workload::Oracle { op: &op, noise },
        K,
        &WireCodecSpec::Identity,
        &x0,
        STEPS,
        seed,
        &TopologySpec::ShardedReduceScatter,
        ExchangePlan::synchronous(),
        &WireOptions::default(),
        &descent,
    )
    .expect("sharded identity wire run");

    let codecs: Vec<Box<dyn Compressor>> = (0..K)
        .map(|_| Box::new(IdentityCompressor::new()) as _)
        .collect();
    let (x_sim, bits_sim, mean_sim) = sim_reference(&op, noise, K, codecs, &x0, STEPS, seed);
    assert_eq!(report.last_mean, mean_sim);
    assert_eq!(report.x, x_sim);
    assert_eq!(report.payload_bits, bits_sim);
}

/// The measured runtime declines what it cannot faithfully time, with typed
/// errors: the ring is modeled-only, and the sharded mesh has no overlapped
/// schedule yet.
#[test]
fn unsupported_wire_plans_are_typed_errors() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let x0 = vec![0.3; D];
    let st = quant_state(ProtocolKind::Main);

    let err = run_wire(
        Workload::Oracle { op: &op, noise },
        K,
        &WireCodecSpec::Quant(st.clone()),
        &x0,
        STEPS,
        11,
        &TopologySpec::Ring,
        ExchangePlan::synchronous(),
        &WireOptions::default(),
        &descent,
    )
    .expect_err("ring has no wire engine");
    assert_eq!(err, CommError::Unsupported { what: "ring wire exchange" });

    let err = run_wire(
        Workload::Oracle { op: &op, noise },
        K,
        &WireCodecSpec::Quant(st),
        &x0,
        STEPS,
        11,
        &TopologySpec::ShardedReduceScatter,
        ExchangePlan::overlapped(1, 0.0),
        &WireOptions::default(),
        &descent,
    )
    .expect_err("the sharded mesh is synchronous-only");
    assert_eq!(
        err,
        CommError::Unsupported { what: "overlapped sharded wire exchange" }
    );
}

/// The overlapped schedule over real sockets follows the threaded engine's
/// depth-stale schedule exactly: same final iterate, same last aggregate,
/// same wire bits as `run_rounds_over` under the same plan.
#[test]
fn overlapped_wire_matches_overlapped_threaded_engine() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let x0 = vec![0.3; D];
    let seed = 47u64;
    let steps = 6;
    let st = quant_state(ProtocolKind::Main);

    for depth in [1usize, 2] {
        let plan = ExchangePlan::overlapped(depth, 0.0);
        let report = run_wire(
            Workload::Oracle { op: &op, noise },
            K,
            &WireCodecSpec::Quant(st.clone()),
            &x0,
            steps,
            seed,
            &TopologySpec::BroadcastAllGather,
            plan,
            &WireOptions::default(),
            &descent,
        )
        .expect("overlapped wire run");

        let threaded = run_rounds_over(
            &op,
            noise,
            K,
            &st,
            x0.clone(),
            steps,
            seed,
            &TopologySpec::BroadcastAllGather,
            &NetworkModel::genesis_cloud(5.0),
            plan,
            |x, mean, t| descent(x, mean, t),
        )
        .expect("threaded run");

        assert_eq!(report.last_mean, threaded.last_mean, "depth {depth}");
        assert_eq!(report.x, threaded.x, "depth {depth}");
        assert_eq!(report.payload_bits, threaded.wire_bits, "depth {depth}");
    }
}

/// Wire-only pins: decoded duals of the last round are deterministic across
/// reruns of the same spec, and folding them through the `v / k` rule in
/// node order reproduces the reported aggregate bit-for-bit (the wire
/// engine really is `decode_aggregate_into`, not a private copy).
#[test]
fn decoded_duals_are_deterministic_and_fold_to_the_mean() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let x0 = vec![0.3; D];
    let st = quant_state(ProtocolKind::Alternating);

    let run = || {
        run_wire(
            Workload::Oracle { op: &op, noise },
            K,
            &WireCodecSpec::Quant(st.clone()),
            &x0,
            STEPS,
            11,
            &TopologySpec::BroadcastAllGather,
            ExchangePlan::synchronous(),
            &WireOptions::default(),
            &descent,
        )
        .expect("wire run")
    };
    let a = run();
    let b = run();
    assert_eq!(a.last_decoded, b.last_decoded);
    assert_eq!(a.last_mean, b.last_mean);
    assert_eq!(a.x, b.x);

    let kf = K as f64;
    let mut fold = vec![0.0f64; D];
    for dec in &a.last_decoded {
        assert_eq!(dec.len(), D);
        for (m, v) in fold.iter_mut().zip(dec) {
            *m += v / kf;
        }
    }
    assert_eq!(fold, a.last_mean);
}

/// Measured-clock bookkeeping: one record per round, per-round splits sum
/// exactly, totals match the per-round sums, and every node's OS-assigned
/// handshake port was actually collected.
#[test]
fn measured_records_are_internally_consistent() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let x0 = vec![0.3; D];

    let report = run_wire(
        Workload::Oracle { op: &op, noise },
        K,
        &WireCodecSpec::Quant(quant_state(ProtocolKind::Main)),
        &x0,
        STEPS,
        11,
        &TopologySpec::BroadcastAllGather,
        ExchangePlan::overlapped(1, 0.0),
        &WireOptions::default(),
        &descent,
    )
    .expect("wire run");

    assert_eq!(report.rounds.len(), STEPS);
    let mut comm = 0.0;
    let mut bits = 0u64;
    for r in &report.rounds {
        assert!(r.gather_s >= 0.0 && r.broadcast_s >= 0.0);
        assert_eq!(r.comm_s, r.gather_s + r.broadcast_s, "round {}", r.round);
        assert_eq!(
            r.comm_exposed_s + r.comm_hidden_s,
            r.comm_s,
            "round {}",
            r.round
        );
        assert!(r.payload_bits > 0);
        assert!(r.frame_bytes > 0);
        comm += r.comm_s;
        bits += r.payload_bits;
    }
    assert_eq!(report.payload_bits, bits);
    assert!((report.comm_s - comm).abs() <= 1e-12 * comm.max(1.0));
    assert!(report.comm_s > 0.0, "a real socket exchange takes nonzero time");
    assert!(report.frame_bytes > 0);
    assert_eq!(report.node_ports.len(), K);
    assert!(report.node_ports.iter().all(|&p| p != 0));

    // synthetic workloads measure without an operator (the timing-bench
    // mode `qoda wire` uses at paper-sized dims)
    let x0s = vec![0.0f64; 64];
    let synth = run_wire(
        Workload::Synthetic { dim: 64, scale: 1.0 },
        2,
        &WireCodecSpec::Identity,
        &x0s,
        3,
        5,
        &TopologySpec::BroadcastAllGather,
        ExchangePlan::synchronous(),
        &WireOptions::default(),
        &descent,
    )
    .expect("synthetic wire run");
    assert_eq!(synth.rounds.len(), 3);
    assert!(synth.payload_bits > 0);
}

/// A worker dying mid-round must surface as `CommError::WorkerLost` —
/// quickly, on every topology and schedule, with no deadlock: the remaining
/// nodes unblock via EOF/timeout cascades, never by hanging the suite.
#[test]
fn killed_worker_surfaces_worker_lost_not_deadlock() {
    let op = test_op();
    let noise = NoiseModel::Absolute { sigma: 0.2 };
    let x0 = vec![0.3; D];
    let opts = WireOptions {
        socket: SocketConfig {
            read_timeout: Duration::from_secs(2),
            write_timeout: Duration::from_secs(2),
            ..SocketConfig::default()
        },
        kill: None,
    };
    let st = quant_state(ProtocolKind::Main);

    // (k, victim, round, topology, plan)
    let cases: Vec<(usize, usize, usize, TopologySpec, ExchangePlan)> = vec![
        // flat, synchronous: the leader's gather EOFs
        (3, 1, 2, TopologySpec::BroadcastAllGather, ExchangePlan::synchronous()),
        // flat, overlapped: the lookahead recv EOFs
        (3, 2, 3, TopologySpec::BroadcastAllGather, ExchangePlan::overlapped(1, 0.0)),
        // hierarchical, rack *member* dies: its rack leader's gather EOFs
        // and the loss cascades up
        (5, 4, 2, TopologySpec::Hierarchical { racks: 2 }, ExchangePlan::synchronous()),
        // hierarchical, rack *leader* dies: both its members and the
        // cluster leader lose a peer
        (5, 3, 2, TopologySpec::Hierarchical { racks: 2 }, ExchangePlan::synchronous()),
        // sharded mesh: a dead peer EOFs every other node's shard exchange
        // and the leader's report gather
        (4, 2, 2, TopologySpec::ShardedReduceScatter, ExchangePlan::synchronous()),
    ];
    for (k, victim, round, topology, plan) in cases {
        let t0 = Instant::now();
        let err = run_wire(
            Workload::Oracle { op: &op, noise },
            k,
            &WireCodecSpec::Quant(st.clone()),
            &x0,
            STEPS,
            11,
            &topology,
            plan,
            &opts.with_kill(victim, round),
            &descent,
        )
        .expect_err("a killed worker must fail the run");
        let elapsed = t0.elapsed();
        assert_eq!(
            err,
            CommError::WorkerLost,
            "k={k} victim={victim} round={round} {topology:?}"
        );
        assert!(
            elapsed < Duration::from_secs(10),
            "cleanup took {elapsed:?} — a deadlock bounded only by timeouts \
             (k={k} victim={victim} {topology:?})"
        );
    }
}

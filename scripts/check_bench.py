#!/usr/bin/env python3
"""Compare a fresh BENCH_comm.json against the committed baseline.

The comm benches (``cargo bench --bench quantize --bench comm_pipeline
--bench topology_comm``) merge machine-readable records into
``results/BENCH_comm.json``. CI's perf gate copies the committed file aside,
re-runs the benches, and calls this script to enforce:

* **presence** — every ``--require PREFIX`` must match at least one fresh
  record, so a bench can't silently stop emitting the numbers the gate
  watches;
* **no regression** — for every record present in both files with an
  ``ns_per_step`` field, the fresh time must stay within
  ``--tolerance`` × the baseline time (absolute ns/step across runners is
  noisy, so the band is wide; the committed baseline pins the *trajectory*,
  not the exact nanosecond);
* **fusion floor** — every fresh record whose name starts with a
  ``--speedup-prefix`` and carries a ``speedup`` field must stay above
  ``min(--min-speedup, 0.7 × baseline speedup)``: the fused kernels must
  not quietly decay back toward the staged path. Speedup is a same-machine
  ratio, which makes it the robust, runner-independent signal.

Records whose name starts with ``_`` are metadata (e.g. the provisional
marker on an estimated baseline) and are ignored. Records whose name starts
with ``wire/`` are *measured* socket latency from the TCP runtime
(``qoda wire``): real wall-clock on whatever runner produced them, so they
are listed as informational and never compared against a baseline — an old
baseline without them (or with different timings) cannot fail the gate.
A ``--require wire/`` can still assert they are being emitted.

Records named ``topology/<plan>/K=<k>`` are the deterministic per-link
accounting of the new collectives (pure ``Transport::charge`` arithmetic,
no timers), gated *within the fresh file*:

* only the ``sharded`` and ``ring`` plans are known — any other name under
  ``topology/`` is a hard error, so a renamed or mistyped record cannot
  silently drop out of the gate;
* every record must carry ``k``, ``peak_link_bytes`` and
  ``flat_peak_link_bytes``;
* ``sharded`` records must satisfy ``peak <= 1.5/K x flat`` — the
  reduce-scatter's reason to exist — and ``ring`` records must stay under
  flat's peak.

Exit code 0 = gate passes; 1 = regression or missing record; 2 = usage/IO
error.
"""

import argparse
import json
import sys


def load(path):
    try:
        with open(path) as f:
            records = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(records, list):
        print(f"check_bench: {path} is not a JSON array", file=sys.stderr)
        sys.exit(2)
    out = {}
    for r in records:
        if isinstance(r, dict) and isinstance(r.get("name"), str):
            out[r["name"]] = r
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed BENCH_comm.json")
    ap.add_argument("--fresh", required=True, help="freshly generated BENCH_comm.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=3.0,
        help="fresh ns_per_step may be at most this factor above baseline (default 3.0)",
    )
    ap.add_argument(
        "--require",
        action="append",
        default=[],
        metavar="PREFIX",
        help="fresh file must contain at least one record with this name prefix",
    )
    ap.add_argument(
        "--min-speedup",
        type=float,
        default=None,
        help="floor for fresh `speedup` records under every --speedup-prefix",
    )
    ap.add_argument(
        "--speedup-prefix",
        action="append",
        default=[],
        metavar="PREFIX",
        help="name prefixes whose `speedup` field is checked against the floor",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    fresh = load(args.fresh)
    failures = []

    provisional = bool(base.get("_meta", {}).get("provisional"))
    if provisional:
        print("note: committed baseline is marked provisional (estimated numbers)")

    for prefix in args.require:
        hits = [n for n in fresh if n.startswith(prefix)]
        if not hits:
            failures.append(f"missing: no fresh record matches prefix {prefix!r}")
        else:
            print(f"present: {prefix!r} -> {len(hits)} record(s)")

    wire = [n for n in sorted(fresh) if n.startswith("wire/")]
    if wire:
        print(
            f"informational: {len(wire)} measured wire/* record(s) "
            "(real socket latency, runner-dependent — never gated)"
        )
        for n in wire:
            ms = fresh[n].get("measured_comm_ms_per_round")
            note = f" {ms} ms/round" if ms is not None else ""
            print(f"  measured  {n}:{note}")

    known_plans = ("sharded", "ring")
    for name in sorted(fresh):
        if not name.startswith("topology/"):
            continue
        rec = fresh[name]
        parts = name.split("/")
        plan = parts[1] if len(parts) > 1 else ""
        if plan not in known_plans:
            failures.append(
                f"topology: unknown plan {plan!r} in record {name!r} "
                f"(known: {', '.join(known_plans)})"
            )
            continue
        try:
            k = int(float(rec["k"]))
            peak = float(rec["peak_link_bytes"])
            flat_peak = float(rec["flat_peak_link_bytes"])
        except (KeyError, TypeError, ValueError):
            failures.append(
                f"topology: {name} must carry numeric k, peak_link_bytes "
                "and flat_peak_link_bytes"
            )
            continue
        if k <= 1 or flat_peak <= 0:
            failures.append(f"topology: {name} has degenerate k={k}/flat={flat_peak}")
            continue
        if plan == "sharded":
            bound = 1.5 / k * flat_peak
            what = f"1.5/K x flat = {bound:.1f}"
        else:
            bound = flat_peak
            what = f"flat's {bound:.1f}"
        verdict = "ok" if peak <= bound else "HOT LINK"
        print(f"{verdict:>10}  {name}: peak {peak:.1f} B/link vs {what} B/link")
        if peak > bound:
            failures.append(
                f"topology: {name} peak link {peak:.1f} B exceeds {what} B"
            )

    compared = 0
    for name, b in sorted(base.items()):
        if name.startswith("_") or name.startswith("wire/"):
            continue
        b_ns = b.get("ns_per_step")
        f_rec = fresh.get(name)
        if b_ns is None or f_rec is None:
            continue
        f_ns = f_rec.get("ns_per_step")
        if f_ns is None:
            continue
        compared += 1
        ratio = f_ns / b_ns if b_ns > 0 else float("inf")
        verdict = "ok" if ratio <= args.tolerance else "REGRESSION"
        print(f"{verdict:>10}  {name}: {b_ns:.0f} -> {f_ns:.0f} ns/step ({ratio:.2f}x)")
        if ratio > args.tolerance:
            failures.append(
                f"regression: {name} is {ratio:.2f}x the baseline "
                f"(tolerance {args.tolerance:.2f}x)"
            )
    print(f"compared {compared} ns/step record(s) at tolerance {args.tolerance:.2f}x")

    if args.min_speedup is not None:
        checked = 0
        for prefix in args.speedup_prefix or [""]:
            for name, f_rec in sorted(fresh.items()):
                if not name.startswith(prefix) or "speedup" not in f_rec:
                    continue
                checked += 1
                got = float(f_rec["speedup"])
                floor = args.min_speedup
                b_rec = base.get(name)
                if b_rec is not None and "speedup" in b_rec:
                    # a committed measured speedup tightens or loosens the
                    # floor to 70% of itself, absorbing runner variance
                    floor = min(floor, 0.7 * float(b_rec["speedup"]))
                verdict = "ok" if got >= floor else "TOO SLOW"
                print(f"{verdict:>10}  {name}: speedup {got:.2f}x (floor {floor:.2f}x)")
                if got < floor:
                    failures.append(
                        f"fusion floor: {name} speedup {got:.2f}x < {floor:.2f}x"
                    )
        if checked == 0:
            failures.append(
                "fusion floor: no fresh speedup records matched "
                f"{args.speedup_prefix!r}"
            )

    if failures:
        print("\nperf gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("perf gate passed")


if __name__ == "__main__":
    main()
